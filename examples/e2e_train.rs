//! End-to-end driver (headline validation run): train the MNIST-like CNN
//! across 125 simulated peers with exact MAR (M=5, G=3 — 5³ = 125), the
//! paper's flagship configuration, and log the loss/accuracy curve plus
//! the full communication ledger. Recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train [iterations]
//! ```

use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;
use marfl::metrics::write_csv;
use marfl::models::default_artifact_dir;
use marfl::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let iterations: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(60);

    let rt = Runtime::new(&default_artifact_dir())?;
    let cfg = ExperimentConfig {
        model: "cnn".into(),
        peers: 125,
        group_size: 5,
        mar_rounds: 3,
        iterations,
        samples_per_peer: 64,
        test_samples: 2000,
        eval_every: 5,
        seed: 2026,
        ..Default::default()
    };
    println!(
        "e2e: MAR-FL | cnn | 125 peers | M=5 G=3 (exact 5^3 grid) | T={iterations} | LDA(1.0) non-iid"
    );
    let wall = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg, &rt)?;
    let summary = trainer.run()?;
    let wall_s = wall.elapsed().as_secs_f64();

    println!("\niter  cum-data(MiB)  cum-ctrl(MiB)  loss    accuracy  sim(s)");
    for p in &summary.curve.points {
        println!(
            "{:>4}  {:>13.1}  {:>13.2}  {:.4}  {:>8.4}  {:>6.1}",
            p.iteration,
            p.data_bytes as f64 / (1 << 20) as f64,
            p.control_bytes as f64 / (1 << 20) as f64,
            p.loss,
            p.accuracy,
            p.sim_time_s
        );
    }
    println!(
        "\nfinal accuracy {:.2}% | loss {:.4} | data {:.1} MiB | control {:.2} MiB ({:.2}% of data) | DHT hops {} | sim {:.0}s | wall {:.0}s",
        summary.final_accuracy * 100.0,
        summary.final_loss,
        summary.comm.data_bytes as f64 / (1 << 20) as f64,
        summary.comm.control_bytes as f64 / (1 << 20) as f64,
        100.0 * summary.comm.control_bytes as f64 / summary.comm.data_bytes as f64,
        summary.dht_hops.unwrap_or(0),
        summary.sim_time_s,
        wall_s,
    );
    let path = std::path::Path::new("results/e2e_train.csv");
    write_csv(path, &summary.curve.csv_rows())?;
    println!("curve -> {}", path.display());
    Ok(())
}
