//! Scenario: a wireless federation under churn — the paper's motivating
//! deployment. Compares a clean 64-peer MAR-FL run against runs with 20%
//! sudden dropouts and 50% participation, demonstrating the resilience
//! claims of §3.2 (Figure 3).
//!
//! ```bash
//! cargo run --release --example churn_resilience
//! ```

use marfl::config::ExperimentConfig;
use marfl::fl::Trainer;
use marfl::models::default_artifact_dir;
use marfl::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(&default_artifact_dir())?;
    let base = ExperimentConfig {
        model: "head".into(),
        peers: 64,
        group_size: 4,
        mar_rounds: 3,
        iterations: 24,
        samples_per_peer: 64,
        test_samples: 1000,
        eval_every: 4,
        seed: 606,
        ..Default::default()
    };

    let scenarios = [
        ("stable network           ", 1.0, 0.0),
        ("20% sudden dropouts      ", 1.0, 0.2),
        ("50% participation        ", 0.5, 0.0),
        ("50% part. + 20% dropouts ", 0.5, 0.2),
    ];

    println!("64-peer MAR-FL federation on the 20NG-like task, T=24\n");
    println!("scenario                    accuracy   data(MiB)   sim(s)");
    let mut rows = Vec::new();
    for (label, participation, dropout) in scenarios {
        let cfg = ExperimentConfig { participation, dropout, ..base.clone() };
        let summary = Trainer::new(cfg, &rt)?.run()?;
        println!(
            "{label}  {:>8.3}  {:>10.1}  {:>7.1}",
            summary.final_accuracy,
            summary.comm.data_bytes as f64 / (1 << 20) as f64,
            summary.sim_time_s
        );
        rows.push((label, summary));
    }

    let clean = rows[0].1.final_accuracy;
    let dropped = rows[1].1.final_accuracy;
    println!(
        "\ndropouts cost {:.1} accuracy points (paper: dropouts alone cause no extra degradation)",
        (clean - dropped) * 100.0
    );
    println!(
        "partial participation is the axis that hurts — {:.3} -> {:.3} at 50%",
        clean, rows[2].1.final_accuracy
    );

    // Bursty wireless availability (Gilbert–Elliott traces): mean Up
    // sojourn 10 iterations, Down 2.5 — ~80% stationary availability but
    // correlated outages, the paper's wireless motivation.
    let mut markov_cfg = base.clone();
    markov_cfg.churn_model = "markov".into();
    markov_cfg.markov_p_down = 0.1;
    markov_cfg.markov_p_up = 0.4;
    let summary = Trainer::new(markov_cfg, &rt)?.run()?;
    println!(
        "\nbursty wireless trace (markov, ~80% availability): acc {:.3}, data {:.1} MiB — \
         MAR-FL's dynamic matchmaking regroups around whoever is present",
        summary.final_accuracy,
        summary.comm.data_bytes as f64 / (1 << 20) as f64
    );
    Ok(())
}
