//! Pure-Rust reference backend: the same model semantics
//! `python/compile/model.py` lowers to HLO, implemented directly over the
//! flat-parameter ABI so the whole system (trainer, KD, DP, benches) runs
//! on machines without the XLA closure or lowered artifacts.
//!
//! Parameter layout matches JAX's `ravel_pytree` over the init dicts
//! (alphabetical key order, row-major leaves):
//!
//! * `head` — MLP 64 → 128(ReLU) → 20:
//!   `fc1_b[128] ‖ fc1_w[64,128] ‖ fc2_b[20] ‖ fc2_w[128,20]` (P = 10900)
//! * `cnn` — conv3×3(1→8, SAME) + ReLU + maxpool2, conv3×3(8→16, SAME) +
//!   ReLU + maxpool2, fc 256 → 64(ReLU) → 10, NHWC:
//!   `conv1_b[8] ‖ conv1_w[3,3,1,8] ‖ conv2_b[16] ‖ conv2_w[3,3,8,16] ‖`
//!   `fc1_b[64] ‖ fc1_w[256,64] ‖ fc2_b[10] ‖ fc2_w[64,10]` (P = 18346)
//!
//! Losses: mean softmax cross-entropy; KD adds Hinton-rescaled
//! `λ·τ²·KL(softmax(z̄/τ) ‖ softmax(s/τ))`. Updates: the damped momentum
//! rule `m' = μ·m + (1−μ)·g`, `θ' = θ − η·m'` over the padded flat vector
//! (padding gradients are zero, so the tail invariant survives).
//!
//! Everything here is stateless and `Sync`; the peer-parallel trainer
//! calls these functions from many `exec` workers at once.

use anyhow::{bail, ensure, Result};

use super::StepOut;
use crate::models::ModelMeta;
use crate::rng::Rng;

// ---------------------------------------------------------------------
// Flat layouts (offsets into theta / the gradient vector)
// ---------------------------------------------------------------------

// head task (20NG-like embeddings)
const H_IN: usize = 64;
const H_HID: usize = 128;
const H_CLS: usize = 20;
const H_FC1_B: usize = 0;
const H_FC1_W: usize = H_FC1_B + H_HID;
const H_FC2_B: usize = H_FC1_W + H_IN * H_HID;
const H_FC2_W: usize = H_FC2_B + H_CLS;
/// head true parameter count (10 900)
pub const HEAD_PARAMS: usize = H_FC2_W + H_HID * H_CLS;

// cnn task (MNIST-like 16×16×1 images)
const IMG: usize = 16;
const C1: usize = 8;
const C2: usize = 16;
const FC_IN: usize = 4 * 4 * C2; // 256, post two maxpools
const FC_HID: usize = 64;
const C_CLS: usize = 10;
const C_C1B: usize = 0;
const C_C1W: usize = C_C1B + C1;
const C_C2B: usize = C_C1W + 3 * 3 * C1;
const C_C2W: usize = C_C2B + C2;
const C_F1B: usize = C_C2W + 3 * 3 * C1 * C2;
const C_F1W: usize = C_F1B + FC_HID;
const C_F2B: usize = C_F1W + FC_IN * FC_HID;
const C_F2W: usize = C_F2B + C_CLS;
/// cnn true parameter count (18 346)
pub const CNN_PARAMS: usize = C_F2W + FC_HID * C_CLS;

fn sl(v: &[f32], off: usize, len: usize) -> &[f32] {
    &v[off..off + len]
}

fn sl_mut(v: &mut [f32], off: usize, len: usize) -> &mut [f32] {
    &mut v[off..off + len]
}

fn check_meta(m: &ModelMeta) -> Result<()> {
    let (params, elems, classes) = match m.name.as_str() {
        "head" => (HEAD_PARAMS, H_IN, H_CLS),
        "cnn" => (CNN_PARAMS, IMG * IMG, C_CLS),
        other => bail!("native backend has no model {other:?}"),
    };
    ensure!(
        m.param_count == params,
        "model {:?}: meta says {} params, native layout has {params}",
        m.name,
        m.param_count
    );
    ensure!(m.padded_len >= params, "padded_len below parameter count");
    ensure!(m.input_elems() == elems, "unexpected input shape");
    ensure!(m.classes == classes, "unexpected class count");
    Ok(())
}

fn batch_of(m: &ModelMeta, x: &[f32], y: &[i32]) -> Result<usize> {
    let elems = m.input_elems();
    ensure!(!y.is_empty() && x.len() == y.len() * elems, "x/y shape mismatch");
    for &yi in y {
        ensure!((0..m.classes as i32).contains(&yi), "label {yi} out of range");
    }
    Ok(y.len())
}

// ---------------------------------------------------------------------
// Dense / conv primitives (f32, matching the lowered kernels)
// ---------------------------------------------------------------------

/// out[b, o] = bias[o] + Σ_i x[b, i] · w[i, o]
fn affine(x: &[f32], w: &[f32], bias: &[f32], b: usize, din: usize, dout: usize, out: &mut [f32]) {
    for bi in 0..b {
        let xrow = &x[bi * din..(bi + 1) * din];
        let orow = &mut out[bi * dout..(bi + 1) * dout];
        orow.copy_from_slice(bias);
        for (i, &xv) in xrow.iter().enumerate() {
            let wrow = &w[i * dout..(i + 1) * dout];
            for (ov, &wv) in orow.iter_mut().zip(wrow) {
                *ov += xv * wv;
            }
        }
    }
}

/// Accumulate dW/db (and optionally dx) for an affine layer given dout.
#[allow(clippy::too_many_arguments)]
fn affine_backward(
    x: &[f32],
    w: &[f32],
    dout_grad: &[f32],
    b: usize,
    din: usize,
    dout: usize,
    dw: &mut [f32],
    db: &mut [f32],
    mut dx: Option<&mut [f32]>,
) {
    for bi in 0..b {
        let xrow = &x[bi * din..(bi + 1) * din];
        let grow = &dout_grad[bi * dout..(bi + 1) * dout];
        for (dbv, &g) in db.iter_mut().zip(grow) {
            *dbv += g;
        }
        for (i, &xv) in xrow.iter().enumerate() {
            let dwrow = &mut dw[i * dout..(i + 1) * dout];
            for (dwv, &g) in dwrow.iter_mut().zip(grow) {
                *dwv += xv * g;
            }
        }
        if let Some(dx) = dx.as_deref_mut() {
            let dxrow = &mut dx[bi * din..(bi + 1) * din];
            for (i, dxv) in dxrow.iter_mut().enumerate() {
                let wrow = &w[i * dout..(i + 1) * dout];
                let mut s = 0.0f32;
                for (&wv, &g) in wrow.iter().zip(grow) {
                    s += wv * g;
                }
                *dxv = s;
            }
        }
    }
}

fn relu_inplace(v: &mut [f32]) {
    for x in v {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

/// Zero grads where the (post-ReLU) activation is zero.
fn relu_mask(grad: &mut [f32], act: &[f32]) {
    for (g, &a) in grad.iter_mut().zip(act) {
        if a <= 0.0 {
            *g = 0.0;
        }
    }
}

/// 3×3 SAME conv, NHWC, stride 1. `w` is `[3,3,cin,cout]` row-major.
#[allow(clippy::too_many_arguments)]
fn conv3x3_same(
    inp: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    w: &[f32],
    bias: &[f32],
    cout: usize,
    out: &mut [f32],
) {
    for bi in 0..b {
        let ibase = bi * hw * hw * cin;
        let obase = bi * hw * hw * cout;
        for y in 0..hw {
            for x in 0..hw {
                let ooff = obase + (y * hw + x) * cout;
                let orow = &mut out[ooff..ooff + cout];
                orow.copy_from_slice(bias);
                for ky in 0..3usize {
                    let sy = y as isize + ky as isize - 1;
                    if sy < 0 || sy >= hw as isize {
                        continue;
                    }
                    for kx in 0..3usize {
                        let sx = x as isize + kx as isize - 1;
                        if sx < 0 || sx >= hw as isize {
                            continue;
                        }
                        let ioff = ibase + (sy as usize * hw + sx as usize) * cin;
                        for i in 0..cin {
                            let iv = inp[ioff + i];
                            let woff = ((ky * 3 + kx) * cin + i) * cout;
                            let wrow = &w[woff..woff + cout];
                            for (ov, &wv) in orow.iter_mut().zip(wrow) {
                                *ov += iv * wv;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Backward of [`conv3x3_same`]: accumulate dW/db and optionally dInp.
#[allow(clippy::too_many_arguments)]
fn conv3x3_same_backward(
    inp: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    w: &[f32],
    cout: usize,
    dout: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    mut dinp: Option<&mut [f32]>,
) {
    for bi in 0..b {
        let ibase = bi * hw * hw * cin;
        let obase = bi * hw * hw * cout;
        for y in 0..hw {
            for x in 0..hw {
                let goff = obase + (y * hw + x) * cout;
                let grow = &dout[goff..goff + cout];
                for (dbv, &g) in db.iter_mut().zip(grow) {
                    *dbv += g;
                }
                for ky in 0..3usize {
                    let sy = y as isize + ky as isize - 1;
                    if sy < 0 || sy >= hw as isize {
                        continue;
                    }
                    for kx in 0..3usize {
                        let sx = x as isize + kx as isize - 1;
                        if sx < 0 || sx >= hw as isize {
                            continue;
                        }
                        let ioff = ibase + (sy as usize * hw + sx as usize) * cin;
                        for i in 0..cin {
                            let iv = inp[ioff + i];
                            let woff = ((ky * 3 + kx) * cin + i) * cout;
                            let dwrow = &mut dw[woff..woff + cout];
                            for (dwv, &g) in dwrow.iter_mut().zip(grow) {
                                *dwv += iv * g;
                            }
                        }
                        if let Some(dinp) = dinp.as_deref_mut() {
                            for i in 0..cin {
                                let woff = ((ky * 3 + kx) * cin + i) * cout;
                                let wrow = &w[woff..woff + cout];
                                let mut s = 0.0f32;
                                for (&wv, &g) in wrow.iter().zip(grow) {
                                    s += wv * g;
                                }
                                dinp[ioff + i] += s;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// 2×2 stride-2 max pool, NHWC; records the argmax flat index per cell.
fn maxpool2(inp: &[f32], b: usize, hw: usize, c: usize, out: &mut [f32], arg: &mut [u32]) {
    let oh = hw / 2;
    for bi in 0..b {
        for y in 0..oh {
            for x in 0..oh {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bidx = 0u32;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            let idx = ((bi * hw + (2 * y + dy)) * hw + (2 * x + dx)) * c
                                + ch;
                            let v = inp[idx];
                            if v > best {
                                best = v;
                                bidx = idx as u32;
                            }
                        }
                    }
                    let oidx = ((bi * oh + y) * oh + x) * c + ch;
                    out[oidx] = best;
                    arg[oidx] = bidx;
                }
            }
        }
    }
}

fn maxpool2_backward(dout: &[f32], arg: &[u32], dinp: &mut [f32]) {
    for (&g, &i) in dout.iter().zip(arg.iter()) {
        dinp[i as usize] += g;
    }
}

// ---------------------------------------------------------------------
// Forward caches
// ---------------------------------------------------------------------

struct HeadCache {
    /// post-ReLU hidden activations [b, 128]
    h: Vec<f32>,
    /// logits [b, 20]
    z: Vec<f32>,
}

fn head_forward(theta: &[f32], x: &[f32], b: usize) -> HeadCache {
    let fc1_b = sl(theta, H_FC1_B, H_HID);
    let fc1_w = sl(theta, H_FC1_W, H_IN * H_HID);
    let fc2_b = sl(theta, H_FC2_B, H_CLS);
    let fc2_w = sl(theta, H_FC2_W, H_HID * H_CLS);
    let mut h = vec![0.0f32; b * H_HID];
    affine(x, fc1_w, fc1_b, b, H_IN, H_HID, &mut h);
    relu_inplace(&mut h);
    let mut z = vec![0.0f32; b * H_CLS];
    affine(&h, fc2_w, fc2_b, b, H_HID, H_CLS, &mut z);
    HeadCache { h, z }
}

fn head_backward(theta: &[f32], x: &[f32], cache: &HeadCache, dz: &[f32], b: usize, g: &mut [f32]) {
    let fc2_w = sl(theta, H_FC2_W, H_HID * H_CLS);
    // decompose the flat gradient into its non-overlapping layer slices
    let (gfc1b, rest) = g.split_at_mut(H_HID);
    let (gfc1w, rest) = rest.split_at_mut(H_IN * H_HID);
    let (gfc2b, rest) = rest.split_at_mut(H_CLS);
    let (gfc2w, _pad) = rest.split_at_mut(H_HID * H_CLS);

    let mut dh = vec![0.0f32; b * H_HID];
    affine_backward(&cache.h, fc2_w, dz, b, H_HID, H_CLS, gfc2w, gfc2b, Some(&mut dh));
    relu_mask(&mut dh, &cache.h);
    affine_backward(x, &[], &dh, b, H_IN, H_HID, gfc1w, gfc1b, None);
}

struct CnnCache {
    /// post-ReLU conv1 activations [b,16,16,8]
    a1: Vec<f32>,
    /// pooled [b,8,8,8]
    p1: Vec<f32>,
    arg1: Vec<u32>,
    /// post-ReLU conv2 activations [b,8,8,16]
    a2: Vec<f32>,
    /// pooled = flat fc input [b,4,4,16] == [b,256]
    p2: Vec<f32>,
    arg2: Vec<u32>,
    /// post-ReLU fc1 activations [b,64]
    h: Vec<f32>,
    /// logits [b,10]
    z: Vec<f32>,
}

fn cnn_forward(theta: &[f32], x: &[f32], b: usize) -> CnnCache {
    let c1b = sl(theta, C_C1B, C1);
    let c1w = sl(theta, C_C1W, 3 * 3 * C1);
    let c2b = sl(theta, C_C2B, C2);
    let c2w = sl(theta, C_C2W, 3 * 3 * C1 * C2);
    let f1b = sl(theta, C_F1B, FC_HID);
    let f1w = sl(theta, C_F1W, FC_IN * FC_HID);
    let f2b = sl(theta, C_F2B, C_CLS);
    let f2w = sl(theta, C_F2W, FC_HID * C_CLS);

    let mut a1 = vec![0.0f32; b * IMG * IMG * C1];
    conv3x3_same(x, b, IMG, 1, c1w, c1b, C1, &mut a1);
    relu_inplace(&mut a1);
    let mut p1 = vec![0.0f32; b * 8 * 8 * C1];
    let mut arg1 = vec![0u32; b * 8 * 8 * C1];
    maxpool2(&a1, b, IMG, C1, &mut p1, &mut arg1);

    let mut a2 = vec![0.0f32; b * 8 * 8 * C2];
    conv3x3_same(&p1, b, 8, C1, c2w, c2b, C2, &mut a2);
    relu_inplace(&mut a2);
    let mut p2 = vec![0.0f32; b * 4 * 4 * C2];
    let mut arg2 = vec![0u32; b * 4 * 4 * C2];
    maxpool2(&a2, b, 8, C2, &mut p2, &mut arg2);

    let mut h = vec![0.0f32; b * FC_HID];
    affine(&p2, f1w, f1b, b, FC_IN, FC_HID, &mut h);
    relu_inplace(&mut h);
    let mut z = vec![0.0f32; b * C_CLS];
    affine(&h, f2w, f2b, b, FC_HID, C_CLS, &mut z);
    CnnCache { a1, p1, arg1, a2, p2, arg2, h, z }
}

fn cnn_backward(theta: &[f32], x: &[f32], cache: &CnnCache, dz: &[f32], b: usize, g: &mut [f32]) {
    let c2w = sl(theta, C_C2W, 3 * 3 * C1 * C2);
    let f1w = sl(theta, C_F1W, FC_IN * FC_HID);
    let f2w = sl(theta, C_F2W, FC_HID * C_CLS);
    // decompose the flat gradient into its non-overlapping layer slices
    let (gc1b, rest) = g.split_at_mut(C1);
    let (gc1w, rest) = rest.split_at_mut(3 * 3 * C1);
    let (gc2b, rest) = rest.split_at_mut(C2);
    let (gc2w, rest) = rest.split_at_mut(3 * 3 * C1 * C2);
    let (gf1b, rest) = rest.split_at_mut(FC_HID);
    let (gf1w, rest) = rest.split_at_mut(FC_IN * FC_HID);
    let (gf2b, rest) = rest.split_at_mut(C_CLS);
    let (gf2w, _pad) = rest.split_at_mut(FC_HID * C_CLS);

    let mut dh = vec![0.0f32; b * FC_HID];
    let mut dp2 = vec![0.0f32; b * FC_IN];
    let mut da2 = vec![0.0f32; b * 8 * 8 * C2];
    let mut dp1 = vec![0.0f32; b * 8 * 8 * C1];
    let mut da1 = vec![0.0f32; b * IMG * IMG * C1];

    // fc head
    affine_backward(&cache.h, f2w, dz, b, FC_HID, C_CLS, gf2w, gf2b, Some(&mut dh));
    relu_mask(&mut dh, &cache.h);
    affine_backward(&cache.p2, f1w, &dh, b, FC_IN, FC_HID, gf1w, gf1b, Some(&mut dp2));

    // conv block 2
    maxpool2_backward(&dp2, &cache.arg2, &mut da2);
    relu_mask(&mut da2, &cache.a2);
    conv3x3_same_backward(
        &cache.p1,
        b,
        8,
        C1,
        c2w,
        C2,
        &da2,
        gc2w,
        gc2b,
        Some(&mut dp1),
    );

    // conv block 1
    maxpool2_backward(&dp1, &cache.arg1, &mut da1);
    relu_mask(&mut da1, &cache.a1);
    conv3x3_same_backward(x, b, IMG, 1, &[], C1, &da1, gc1w, gc1b, None);
}

// ---------------------------------------------------------------------
// Losses
// ---------------------------------------------------------------------

/// Mean softmax cross-entropy and its logit gradient `(p − onehot)/B`.
fn ce_loss_grad(z: &[f32], y: &[i32], rows: usize, classes: usize) -> (f32, Vec<f32>) {
    let mut dz = vec![0.0f32; rows * classes];
    let invb = 1.0 / rows as f32;
    let mut loss = 0.0f64;
    for r in 0..rows {
        let zr = &z[r * classes..(r + 1) * classes];
        let dr = &mut dz[r * classes..(r + 1) * classes];
        let max = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for (&zv, d) in zr.iter().zip(dr.iter_mut()) {
            let e = (zv - max).exp();
            *d = e;
            denom += e;
        }
        let yi = y[r] as usize;
        loss += (denom.ln() + max - zr[yi]) as f64;
        for d in dr.iter_mut() {
            *d = *d / denom * invb;
        }
        dr[yi] -= invb;
    }
    ((loss / rows as f64) as f32, dz)
}

/// Softened softmax probabilities of one logit row at temperature τ.
fn softmax_tau(zr: &[f32], tau: f32, out: &mut [f32]) {
    let max = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max) / tau;
    let mut denom = 0.0f32;
    for (&zv, o) in zr.iter().zip(out.iter_mut()) {
        let e = (zv / tau - max).exp();
        *o = e;
        denom += e;
    }
    for o in out.iter_mut() {
        *o /= denom;
    }
}

/// KD loss `L = (1−λ)·CE + λ·τ²·KL(p_t ‖ p_s)` (Hinton rescaling) and its
/// logit gradient `(1−λ)·dCE + (λ·τ/B)·(p_s − p_t)`. With λ = 0 this is
/// exactly [`ce_loss_grad`].
#[allow(clippy::too_many_arguments)]
fn kd_loss_grad(
    z: &[f32],
    y: &[i32],
    zbar: &[f32],
    lam: f32,
    tau: f32,
    rows: usize,
    classes: usize,
) -> (f32, Vec<f32>) {
    let (ce, mut dz) = ce_loss_grad(z, y, rows, classes);
    for d in dz.iter_mut() {
        *d *= 1.0 - lam;
    }
    let mut ps = vec![0.0f32; classes];
    let mut pt = vec![0.0f32; classes];
    let mut kl_mean = 0.0f64;
    let scale = lam * tau / rows as f32;
    for r in 0..rows {
        let zr = &z[r * classes..(r + 1) * classes];
        let tr = &zbar[r * classes..(r + 1) * classes];
        softmax_tau(zr, tau, &mut ps);
        softmax_tau(tr, tau, &mut pt);
        let mut kl = 0.0f64;
        for c in 0..classes {
            if pt[c] > 0.0 {
                kl += pt[c] as f64 * ((pt[c] as f64).ln() - (ps[c].max(1e-30) as f64).ln());
            }
        }
        kl_mean += kl;
        let dr = &mut dz[r * classes..(r + 1) * classes];
        for c in 0..classes {
            dr[c] += scale * (ps[c] - pt[c]);
        }
    }
    kl_mean /= rows as f64;
    let loss = (1.0 - lam) * ce + lam * tau * tau * (kl_mean as f32);
    (loss, dz)
}

// ---------------------------------------------------------------------
// Entry points (called by the Runtime facade)
// ---------------------------------------------------------------------

/// Forward + loss-grad + backward + damped momentum, generically over the
/// loss's logit gradient.
#[allow(clippy::too_many_arguments)]
fn step_with<F>(
    m: &ModelMeta,
    theta: &[f32],
    momentum: &[f32],
    x: &[f32],
    b: usize,
    eta: f32,
    mu: f32,
    loss_grad: F,
) -> Result<StepOut>
where
    F: FnOnce(&[f32]) -> (f32, Vec<f32>),
{
    ensure!(theta.len() == m.padded_len, "theta length mismatch");
    ensure!(momentum.len() == m.padded_len, "momentum length mismatch");
    let mut g = vec![0.0f32; m.padded_len];
    let loss = match m.name.as_str() {
        "head" => {
            let cache = head_forward(theta, x, b);
            let (loss, dz) = loss_grad(&cache.z);
            head_backward(theta, x, &cache, &dz, b, &mut g);
            loss
        }
        "cnn" => {
            let cache = cnn_forward(theta, x, b);
            let (loss, dz) = loss_grad(&cache.z);
            cnn_backward(theta, x, &cache, &dz, b, &mut g);
            loss
        }
        other => bail!("native backend has no model {other:?}"),
    };
    // fused damped-momentum update over the padded flat vector
    let mut theta2 = Vec::with_capacity(theta.len());
    let mut mom2 = Vec::with_capacity(momentum.len());
    for ((&t, &mv), &gv) in theta.iter().zip(momentum).zip(&g) {
        let mn = mu * mv + (1.0 - mu) * gv;
        mom2.push(mn);
        theta2.push(t - eta * mn);
    }
    Ok(StepOut { theta: theta2, momentum: mom2, loss })
}

/// One local momentum-SGD step over a batch.
pub fn train_step(
    m: &ModelMeta,
    theta: &[f32],
    momentum: &[f32],
    x: &[f32],
    y: &[i32],
    eta: f32,
    mu: f32,
) -> Result<StepOut> {
    check_meta(m)?;
    let b = batch_of(m, x, y)?;
    step_with(m, theta, momentum, x, b, eta, mu, |z| {
        ce_loss_grad(z, y, b, m.classes)
    })
}

/// One Moshpit-KD student step (Algorithm 2). τ is the lowering-time KD
/// temperature (`meta.kd_tau`).
#[allow(clippy::too_many_arguments)]
pub fn kd_step(
    m: &ModelMeta,
    theta: &[f32],
    momentum: &[f32],
    x: &[f32],
    y: &[i32],
    zbar: &[f32],
    lambda: f32,
    tau: f32,
    eta: f32,
    mu: f32,
) -> Result<StepOut> {
    check_meta(m)?;
    let b = batch_of(m, x, y)?;
    ensure!(zbar.len() == b * m.classes, "zbar shape mismatch");
    ensure!(tau > 0.0, "KD temperature must be positive");
    step_with(m, theta, momentum, x, b, eta, mu, |z| {
        kd_loss_grad(z, y, zbar, lambda, tau, b, m.classes)
    })
}

/// Forward pass: logits for a batch.
pub fn logits(m: &ModelMeta, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
    check_meta(m)?;
    let elems = m.input_elems();
    ensure!(!x.is_empty() && x.len() % elems == 0, "x shape mismatch");
    let b = x.len() / elems;
    ensure!(theta.len() == m.padded_len, "theta length mismatch");
    Ok(match m.name.as_str() {
        "head" => head_forward(theta, x, b).z,
        "cnn" => cnn_forward(theta, x, b).z,
        other => bail!("native backend has no model {other:?}"),
    })
}

/// One eval chunk: (summed NLL, correct count).
pub fn eval_chunk(m: &ModelMeta, theta: &[f32], x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
    check_meta(m)?;
    let rows = batch_of(m, x, y)?;
    let z = logits(m, theta, x)?;
    let c = m.classes;
    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f64;
    for r in 0..rows {
        let zr = &z[r * c..(r + 1) * c];
        let max = zr.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let denom: f32 = zr.iter().map(|&v| (v - max).exp()).sum();
        loss_sum += (denom.ln() + max - zr[y[r] as usize]) as f64;
        let mut best = 0usize;
        for (j, &v) in zr.iter().enumerate() {
            if v > zr[best] {
                best = j;
            }
        }
        if best == y[r] as usize {
            correct += 1.0;
        }
    }
    Ok((loss_sum, correct))
}

/// Mean of `k` stacked flat vectors (`stack` row-major `[k, padded_len]`),
/// through the same allocation-free f64 strip kernel the aggregators use.
pub fn group_mean(m: &ModelMeta, stack: &[f32], k: usize) -> Result<Vec<f32>> {
    let p = m.padded_len;
    ensure!(k > 0 && stack.len() == k * p, "stack shape mismatch");
    let mut out = vec![0.0f32; p];
    crate::aggregation::mean_indexed_into(k, |r| &stack[r * p..(r + 1) * p], &mut out, true);
    Ok(out)
}

/// Deterministic He initialization over the flat layout (weights
/// `N(0, 2/fan_in)`, biases zero, zero tail padding) — the artifact-free
/// stand-in for `{m}_init.bin`. Every call returns the same θ⁰, so all
/// peers share it (paper §2.2).
pub fn init_params(m: &ModelMeta) -> Result<Vec<f32>> {
    check_meta(m)?;
    let mut theta = vec![0.0f32; m.padded_len];
    fn he_fill(slice: &mut [f32], fan_in: usize, rng: &mut Rng) {
        let std = (2.0 / fan_in as f64).sqrt();
        for v in slice {
            *v = (rng.normal() * std) as f32;
        }
    }
    match m.name.as_str() {
        "head" => {
            let mut rng = Rng::new(0x4EAD_5EED);
            he_fill(sl_mut(&mut theta, H_FC1_W, H_IN * H_HID), H_IN, &mut rng);
            he_fill(sl_mut(&mut theta, H_FC2_W, H_HID * H_CLS), H_HID, &mut rng);
        }
        "cnn" => {
            let mut rng = Rng::new(0xC4_45EED);
            he_fill(sl_mut(&mut theta, C_C1W, 3 * 3 * C1), 9, &mut rng);
            he_fill(sl_mut(&mut theta, C_C2W, 3 * 3 * C1 * C2), 9 * C1, &mut rng);
            he_fill(sl_mut(&mut theta, C_F1W, FC_IN * FC_HID), FC_IN, &mut rng);
            he_fill(sl_mut(&mut theta, C_F2W, FC_HID * C_CLS), FC_HID, &mut rng);
        }
        other => bail!("native backend has no model {other:?}"),
    }
    Ok(theta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ArtifactMeta;

    fn meta() -> ArtifactMeta {
        ArtifactMeta::builtin(std::path::Path::new("/nonexistent"))
    }

    fn head_meta() -> ModelMeta {
        meta().model("head").unwrap().clone()
    }

    fn cnn_meta() -> ModelMeta {
        meta().model("cnn").unwrap().clone()
    }

    #[test]
    fn layout_counts_match_registry() {
        assert_eq!(HEAD_PARAMS, 10_900);
        assert_eq!(CNN_PARAMS, 18_346);
        assert_eq!(head_meta().param_count, HEAD_PARAMS);
        assert_eq!(cnn_meta().param_count, CNN_PARAMS);
    }

    #[test]
    fn init_is_deterministic_with_zero_bias_and_tail() {
        for m in [head_meta(), cnn_meta()] {
            let a = init_params(&m).unwrap();
            let b = init_params(&m).unwrap();
            assert_eq!(a, b);
            assert_eq!(a.len(), m.padded_len);
            assert!(a[m.param_count..].iter().all(|&v| v == 0.0));
            assert!(a.iter().any(|&v| v != 0.0));
        }
        // head biases (layout prefix) are zero
        let h = init_params(&head_meta()).unwrap();
        assert!(h[..H_HID].iter().all(|&v| v == 0.0));
    }

    /// Central finite differences against the analytic gradient — the
    /// correctness anchor for the whole backward implementation.
    fn fd_check(m: &ModelMeta, probes: &[usize]) {
        let mut rng = Rng::new(0xFD);
        let theta = init_params(m).unwrap();
        let b = 4;
        let x: Vec<f32> =
            (0..b * m.input_elems()).map(|_| rng.normal() as f32 * 0.5).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % m.classes) as i32).collect();

        // analytic gradient via a (η=1, μ=0) step: θ' = θ − g
        let mom = vec![0.0f32; theta.len()];
        let out = train_step(m, &theta, &mom, &x, &y, 1.0, 0.0).unwrap();
        let grad: Vec<f32> =
            theta.iter().zip(&out.theta).map(|(&t, &t2)| t - t2).collect();

        let loss_at = |th: &[f32]| -> f64 {
            let o = train_step(m, th, &mom, &x, &y, 0.0, 0.0).unwrap();
            o.loss as f64
        };
        let eps = 2e-2f64;
        for &j in probes {
            let mut tp = theta.clone();
            tp[j] += eps as f32;
            let lp = loss_at(&tp);
            tp[j] = theta[j] - eps as f32;
            let lm = loss_at(&tp);
            let fd = (lp - lm) / (2.0 * eps);
            let an = grad[j] as f64;
            assert!(
                (fd - an).abs() <= 2e-3 + 0.08 * an.abs().max(fd.abs()),
                "param {j}: fd {fd:.6} vs analytic {an:.6}"
            );
        }
    }

    #[test]
    fn head_gradients_match_finite_differences() {
        // probe biases and weights in both layers
        fd_check(
            &head_meta(),
            &[0, 5, H_FC1_W + 3, H_FC1_W + 1000, H_FC2_B + 2, H_FC2_W + 7, H_FC2_W + 999],
        );
    }

    #[test]
    fn cnn_gradients_match_finite_differences() {
        fd_check(
            &cnn_meta(),
            &[
                C_C1B + 1,
                C_C1W + 10,
                C_C2B + 3,
                C_C2W + 100,
                C_F1B + 5,
                C_F1W + 5000,
                C_F2B + 4,
                C_F2W + 123,
            ],
        );
    }

    #[test]
    fn kd_step_lambda_zero_equals_train_step() {
        let m = head_meta();
        let mut rng = Rng::new(3);
        let theta = init_params(&m).unwrap();
        let mom = vec![0.0f32; theta.len()];
        let b = m.batch;
        let x: Vec<f32> =
            (0..b * m.input_elems()).map(|_| rng.normal() as f32).collect();
        let y: Vec<i32> = (0..b).map(|i| (i % m.classes) as i32).collect();
        let zbar = vec![0.0f32; b * m.classes];
        let a = train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
        let k = kd_step(&m, &theta, &mom, &x, &y, &zbar, 0.0, 3.0, 0.1, 0.9).unwrap();
        assert_eq!(a.theta, k.theta, "λ=0 KD must equal plain CE training");
        assert!((a.loss - k.loss).abs() < 1e-7);
    }

    #[test]
    fn momentum_rule_matches_hand_computation() {
        // single logit parameter view: check m' = μm + (1−μ)g, θ' = θ−ηm'
        let m = head_meta();
        let theta = init_params(&m).unwrap();
        let mom = vec![0.25f32; theta.len()];
        let mut rng = Rng::new(4);
        let b = 2;
        let x: Vec<f32> =
            (0..b * m.input_elems()).map(|_| rng.normal() as f32).collect();
        let y = vec![0i32, 1];
        // g via η=1, μ=0 from zero momentum
        let zero = vec![0.0f32; theta.len()];
        let gstep = train_step(&m, &theta, &zero, &x, &y, 1.0, 0.0).unwrap();
        let g: Vec<f32> =
            theta.iter().zip(&gstep.theta).map(|(&t, &t2)| t - t2).collect();
        let out = train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
        for j in [0usize, H_FC1_W + 17, H_FC2_W + 40] {
            let want_m = 0.9 * mom[j] + 0.1 * g[j];
            assert!((out.momentum[j] - want_m).abs() < 1e-5);
            let want_t = theta[j] - 0.1 * out.momentum[j];
            assert!((out.theta[j] - want_t).abs() < 1e-6);
        }
    }

    #[test]
    fn training_reduces_loss_on_fixed_batch() {
        let m = head_meta();
        let mut rng = Rng::new(5);
        let data = crate::data::synth::newsgroups_like(m.batch, &mut rng);
        let idx: Vec<usize> = (0..m.batch).collect();
        let (x, y) = data.gather(&idx);
        let mut theta = init_params(&m).unwrap();
        let mut mom = vec![0.0f32; theta.len()];
        let mut first = 0.0f32;
        let mut last = 0.0f32;
        for s in 0..25 {
            let out = train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
            theta = out.theta;
            mom = out.momentum;
            if s == 0 {
                first = out.loss;
            }
            last = out.loss;
        }
        assert!(last < first * 0.6, "loss {first} -> {last}");
    }

    #[test]
    fn eval_chunk_counts_and_losses_are_sane() {
        let m = head_meta();
        let mut rng = Rng::new(6);
        let data = crate::data::synth::newsgroups_like(40, &mut rng);
        let theta = init_params(&m).unwrap();
        let (loss_sum, correct) =
            eval_chunk(&m, &theta, &data.x, &data.y).unwrap();
        assert!(loss_sum > 0.0 && loss_sum.is_finite());
        assert!((0.0..=40.0).contains(&correct));
    }

    #[test]
    fn group_mean_is_exact_mean() {
        let m = head_meta();
        let p = m.padded_len;
        let mut rng = Rng::new(7);
        let stack: Vec<f32> = (0..3 * p).map(|_| rng.normal() as f32).collect();
        let got = group_mean(&m, &stack, 3).unwrap();
        // same operation order as the strip kernel: f64 sum, then * (1/k)
        let inv = 1.0f64 / 3.0;
        for j in (0..p).step_by(997) {
            let want = ((stack[j] as f64 + stack[p + j] as f64 + stack[2 * p + j] as f64)
                * inv) as f32;
            assert_eq!(got[j], want);
        }
    }
}
