//! Moshpit All-Reduce aggregator — the paper's system contribution.
//!
//! Per FL iteration, `aggregate` runs G MAR rounds. Each round:
//!
//! 1. **Matchmaking** — every aggregator announces itself on the Kademlia
//!    DHT under its reduced group key (`store`), then collects its group
//!    (`get`). Only lightweight metadata crosses the DHT; model weights
//!    never do (control plane, O(N log N) small messages per round).
//! 2. **Group exchange** — each group performs a full-gather of member
//!    states ((k−1) state transfers per member, data plane) and averages
//!    via the Pallas `group_mean` artifact (native fallback otherwise).
//! 3. **Key update** — each member's round-g coordinate becomes its chunk
//!    index within its group (no-revisit; see `group_key`).
//!
//! With `|A_t| = M^d` the schedule is the exact hypercube all-reduce; any
//! other count runs the approximate mode that converges across iterations
//! (Eq. 1 / `mixing.rs`).
//!
//! The control plane is **pipelined**: round g+1's matchmaking depends
//! only on round g's membership + pre-drawn drop plan (the chunk-index
//! key update), never on the averaged values, so it runs concurrently
//! with round g's group exchange. The simulated clock models the overlap
//! with `SimClock::pipelined_two_phase` — only round 0's matchmaking sits
//! on the critical path in full.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use super::group_key::{grid_keys, perfect_grid, random_keys, GroupKey};
use crate::aggregation::robust::{GroupScores, RobustPolicy};
use crate::aggregation::{
    book_full_gather_faulty, book_group_exchange_fabric,
    book_group_exchange_mode, book_reduce_scatter_fabric,
    book_reduce_scatter_faulty, payload_bytes, robust_average_group,
    robust_average_group_chunked, robust_average_group_native,
    robust_average_views, robust_average_views_chunked, AggCtx, AggReport,
    Aggregate, ExchangeTiming, GroupExchange, PeerState,
};
use crate::attack::{RepEvent, Reputation};
use crate::exec;
use crate::dht::{decode_peer, encode_peer, Key, SimDht};
use crate::metrics::CommLedger;
use crate::net::{Fabric, FaultCounters, LinkFault};
use crate::rng::Rng;
use crate::telemetry::{EventKind, TraceHandle};

/// Construction-time options for [`MarAggregator`] — one struct consumed
/// at construction in place of the old `with_exchange`/`with_rs_drop`/
/// `with_robust`/`with_reputation`/… builder sprawl. `Default` is the
/// seed configuration: full-gather, no drops, parallel lanes, plain mean,
/// reputation off, no trace.
#[derive(Clone, Debug)]
pub struct AggOptions {
    /// Within-group wire protocol (see [`MarAggregator::exchange`]).
    pub exchange: GroupExchange,
    /// Chunk-owner drop probability (see [`MarAggregator::rs_drop`]).
    pub rs_drop: f64,
    /// Owner-drop retry budget (see [`MarAggregator::rs_retry_budget`]).
    pub rs_retry_budget: usize,
    /// Parallel group lanes (see [`MarAggregator::parallel`]).
    pub parallel: bool,
    /// Within-group robust center (see [`MarAggregator::robust`]).
    pub robust: RobustPolicy,
    /// Reputation ban threshold; `<= 0` disables the ledger entirely —
    /// no per-group distance work, no behavioural change.
    pub rep_threshold: f64,
    /// Per-iteration reputation decay toward neutral (`0` = sticky).
    pub rep_decay: f64,
    /// Ban length under parole (`0` = legacy fixed-length sticky bans).
    pub parole_rounds: u64,
    /// Round-event trace sink. Recording happens only in serial schedule
    /// phases; `None` (default) keeps runs bit-identical to the seed.
    pub trace: Option<TraceHandle>,
}

impl Default for AggOptions {
    fn default() -> Self {
        AggOptions {
            exchange: GroupExchange::FullGather,
            rs_drop: 0.0,
            rs_retry_budget: 0,
            parallel: true,
            robust: RobustPolicy::MEAN,
            rep_threshold: 0.0,
            rep_decay: 0.0,
            parole_rounds: 0,
            trace: None,
        }
    }
}

/// MAR-FL's aggregator: owns the DHT control plane and the group-key
/// schedule.
pub struct MarAggregator {
    /// group size M
    pub group_size: usize,
    /// MAR rounds G per FL iteration
    pub rounds: usize,
    /// within-group wire protocol (full-gather default; reduce-scatter
    /// is the Moshpit-SGD chunked mode, `mar.reduce_scatter` ablation)
    pub exchange: GroupExchange,
    /// probability that a reduce-scatter group loses one member (a chunk
    /// owner) mid-exchange. Chunk ownership makes every member
    /// load-bearing — the missing stripe stalls the whole group (the
    /// reliability limitation `butterfly.rs` documents for BAR) — so the
    /// survivors time out and redo the exchange as a full gather among
    /// themselves; the dropped peer goes stale and sits out the rest of
    /// the iteration. No effect under full-gather.
    pub rs_drop: f64,
    /// per-iteration budget of owner-drop *retries* (`mar.rs_retry_budget`):
    /// while budget remains and a later round exists to re-form in, a
    /// group that loses a chunk owner defers — survivors skip averaging
    /// (and the full-gather recovery bytes) and simply re-announce in the
    /// next round's matchmaking. Once the budget is spent, and always in
    /// an iteration's final round, drops fall back to the survivors-only
    /// full gather. 0 (default) reproduces the immediate-fallback seed
    /// behavior exactly.
    pub rs_retry_budget: usize,
    /// run each round's groups concurrently on the `exec` pool (default).
    /// The serial path is kept as the bit-identical reference for the
    /// determinism tests and the serial-vs-parallel scaling bench.
    pub parallel: bool,
    /// within-group robust center (`attack.robust`). `Mean` (default)
    /// runs the exact legacy averaging bit for bit; the other estimators
    /// bound the pull any single Byzantine member exerts on the group
    /// center (see [`crate::aggregation::robust`]).
    pub robust: RobustPolicy,
    /// reputation ledger gating matchmaking (`attack.rep_threshold`);
    /// `None` disables scoring entirely — no per-group distance work, no
    /// behavioural change
    rep: Option<Reputation>,
    dht: SimDht,
    /// peer index -> DHT node id
    node_ids: Vec<Key>,
    /// FL-iteration counter (scopes DHT announcement keys)
    iteration: usize,
    /// peers (indices into `states`) that crash-faulted during the most
    /// recent `aggregate` call — the Trainer collects them via
    /// [`Self::take_crashed`] to mark them stale / push their Markov
    /// chains Down
    crashed_last: Vec<usize>,
    /// round-event trace sink ([`AggOptions::trace`]); recorded only in
    /// serial schedule phases, so serial ≡ parallel byte-for-byte
    trace: Option<TraceHandle>,
}

impl MarAggregator {
    /// Build the control plane with the seed defaults: every peer joins
    /// the DHT once at startup. Shorthand for [`Self::with_options`] with
    /// `AggOptions::default()`.
    pub fn new(
        n_peers: usize,
        group_size: usize,
        rounds: usize,
        ledger: Arc<CommLedger>,
        seed: u64,
    ) -> Self {
        Self::with_options(n_peers, group_size, rounds, ledger, seed, AggOptions::default())
    }

    /// Build the control plane with explicit [`AggOptions`]. Reputation
    /// gating activates when `opts.rep_threshold > 0`: each group's
    /// members are scored by their distance to the group's robust
    /// center, folded into an EWMA reputation, and peers whose
    /// reputation falls below the threshold stop announcing on the DHT
    /// for a few iterations (bounded ban count, probational rejoin /
    /// parole — see [`Reputation`]). Because the control plane is
    /// pipelined (round g+1's membership is fixed before round g's
    /// scores exist), a ban takes effect from the *next* `aggregate`
    /// call, never mid-iteration.
    pub fn with_options(
        n_peers: usize,
        group_size: usize,
        rounds: usize,
        ledger: Arc<CommLedger>,
        seed: u64,
        opts: AggOptions,
    ) -> Self {
        assert!(group_size >= 2);
        assert!(rounds >= 1);
        assert!(
            (0.0..=1.0).contains(&opts.rs_drop),
            "rs_drop {} outside [0, 1]",
            opts.rs_drop
        );
        let mut dht = SimDht::new(ledger);
        let mut rng = Rng::new(seed ^ 0xD47);
        let node_ids: Vec<Key> =
            (0..n_peers).map(|_| Key::random(&mut rng)).collect();
        for id in &node_ids {
            dht.join(*id);
        }
        let rep = (opts.rep_threshold > 0.0).then(|| {
            let mut r = Reputation::new(n_peers, opts.rep_threshold)
                .with_parole(opts.rep_decay, opts.parole_rounds);
            // ban/parole transitions feed the trace; logging is armed
            // only when someone will drain it
            r.log_events(opts.trace.is_some());
            r
        });
        MarAggregator {
            group_size,
            rounds,
            exchange: opts.exchange,
            rs_drop: opts.rs_drop,
            rs_retry_budget: opts.rs_retry_budget,
            parallel: opts.parallel,
            robust: opts.robust,
            rep,
            dht,
            node_ids,
            iteration: 0,
            crashed_last: Vec::new(),
            trace: opts.trace,
        }
    }

    /// Record one trace event at simulated time `t` (no-op untraced).
    fn trace_ev(&self, t: f64, kind: EventKind) {
        if let Some(tr) = &self.trace {
            tr.lock().unwrap().record(self.iteration as u64, t, kind);
        }
    }

    /// The reputation ledger, when enabled (`AggOptions::rep_threshold`).
    pub fn reputation(&self) -> Option<&Reputation> {
        self.rep.as_ref()
    }

    /// Drain the peers that crash-faulted during the last `aggregate`
    /// call (indices into the `states` slice). Empty unless the fault
    /// plan's `crash_prob` is active.
    pub fn take_crashed(&mut self) -> Vec<usize> {
        std::mem::take(&mut self.crashed_last)
    }

    /// DHT-mediated matchmaking for one round. `positions[i]` announces
    /// under `keys[i].reduced(round)`; groups are peers sharing a reduced
    /// key, split into chunks of at most M (sorted by peer id for
    /// determinism). Positions with `alive[pos] == false` (chunk owners
    /// that dropped in an earlier round of this iteration) neither
    /// announce nor collect. Returns groups as lists of *positions* into
    /// `agg`.
    fn matchmake(
        &mut self,
        agg: &[usize],
        keys: &[GroupKey],
        alive: &[bool],
        round: usize,
        scope: &str,
    ) -> Vec<Vec<usize>> {
        // announce: one DHT store per live aggregator
        let mut content_keys: Vec<Key> = Vec::with_capacity(agg.len());
        for (pos, &peer) in agg.iter().enumerate() {
            let content =
                Key::hash_of(&format!("{scope}:r{round}:{}", keys[pos].reduced(round)));
            content_keys.push(content);
            if alive[pos] {
                self.dht.store(self.node_ids[peer], content, encode_peer(pos));
            }
        }
        // collect: every aggregator issues its own get (the paper's
        // dispatcher scans peer announcements — O(N) lookups per round);
        // all members of a group see the same set, which doubles as the
        // paper's "group symmetry" cross-check
        let mut by_key: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (pos, &peer) in agg.iter().enumerate() {
            if !alive[pos] {
                continue;
            }
            let got = self.dht.get(self.node_ids[peer], content_keys[pos]);
            let mut members: Vec<usize> =
                got.iter().filter_map(|v| decode_peer(v)).collect();
            members.sort_unstable();
            members.dedup();
            debug_assert!(members.contains(&pos), "announcer missing from own group");
            let reduced = keys[pos].reduced(round);
            match by_key.get(&reduced) {
                Some(existing) => debug_assert_eq!(
                    existing, &members,
                    "group symmetry violated for key {reduced}"
                ),
                None => {
                    by_key.insert(reduced, members);
                }
            }
        }
        // clear ephemeral announcements (dispatcher stale-entry sweep)
        for ck in content_keys {
            self.dht.clear(ck);
        }
        // split oversize collections into chunks of at most M
        let mut groups = Vec::new();
        for (_, members) in by_key {
            for chunk in members.chunks(self.group_size) {
                groups.push(chunk.to_vec());
            }
        }
        groups
    }

    /// Cumulative DHT lookup hops (diagnostics / control-plane model).
    pub fn dht_hops(&self) -> u64 {
        self.dht.hops_total()
    }

    /// Simulated control-plane latency of one matchmaking pass that cost
    /// `hops` DHT hops across `live` announcing peers: announcements and
    /// collects run in parallel across peers, so the pass lasts the
    /// per-peer average lookup depth (2 RTTs per hop: request+response).
    fn matchmaking_latency(fabric: &Fabric, hops: u64, live: usize) -> f64 {
        let avg_hops = hops as f64 / live.max(1) as f64;
        2.0 * fabric.latency * (1.0 + avg_hops)
    }

    /// One timed matchmaking pass: the hops-delta measurement around
    /// [`Self::matchmake`], converted into control-plane latency over
    /// `fabric` with the live announcer count as the denominator — the
    /// single definition every matchmaking pass (round 0, pipelined
    /// round g+1, MKD) shares.
    fn matchmake_timed(
        &mut self,
        agg: &[usize],
        keys: &[GroupKey],
        alive: &[bool],
        round: usize,
        scope: &str,
        fabric: &Fabric,
    ) -> (Vec<Vec<usize>>, f64) {
        let hops_before = self.dht.hops_total();
        let groups = self.matchmake(agg, keys, alive, round, scope);
        let live = alive.iter().filter(|&&a| a).count();
        let control_s = Self::matchmaking_latency(
            fabric,
            self.dht.hops_total() - hops_before,
            live,
        );
        (groups, control_s)
    }

    /// One standalone DHT-matchmade grouping round over `agg` with fresh
    /// uniform keys — Moshpit-KD collects candidate teachers "using the
    /// same procedure MAR uses for global model averaging" (paper §2.2).
    /// `tag` must be unique per call (it scopes the DHT announcements).
    /// Returns the groups (as *positions into `agg`*) plus the pass's
    /// simulated control-plane latency over `fabric` — what the
    /// pipelined MKD engine overlaps with the previous round's teacher
    /// exchange.
    pub fn form_groups_once_timed(
        &mut self,
        agg: &[usize],
        rng: &mut Rng,
        tag: &str,
        fabric: &Fabric,
    ) -> (Vec<Vec<usize>>, f64) {
        let keys = random_keys(agg.len(), self.group_size, 1, rng);
        // reputation bans gate every matchmaking pass, including MKD's;
        // a ban that excludes someone here is *effective* (it shaped
        // membership) and counts toward the flag scorecard
        let mut alive = vec![true; agg.len()];
        if let Some(rep) = self.rep.as_mut() {
            for (pos, &peer) in agg.iter().enumerate() {
                if rep.is_banned(peer) {
                    alive[pos] = false;
                    rep.note_gated(peer);
                }
            }
        }
        self.matchmake_timed(agg, &keys, &alive, 0, tag, fabric)
    }
}

/// Pre-drawn outcome for one group in one round — schedule state,
/// decided serially (RNG + retry-budget counter) before the group
/// fan-out so parallel lanes stay bit-identical to the serial reference.
/// Generalizes the original chunk-owner `DropPlan` to arbitrary member
/// loss: the legacy `rs_drop` victim, fault-plan crashes, and messages
/// that exhausted their retry budget all land in the same lost set.
#[derive(Clone, Debug, PartialEq, Eq)]
enum GroupPlan {
    /// nobody lost: normal exchange
    Keep,
    /// lost chunk indices; survivors abort after the timeout and
    /// re-form via the next round's matchmaking (`mar.rs_retry_budget`)
    Retry(Vec<usize>),
    /// lost chunk indices; the surviving quorum redoes the exchange as
    /// a renormalized full gather among themselves (the seed's
    /// single-victim `Fallback`, generalized)
    Degraded(Vec<usize>),
    /// lost chunk indices left fewer than `quorum_min` survivors: the
    /// group times out without averaging (fault plan only — the legacy
    /// path always proceeds, matching seed behavior)
    Abort(Vec<usize>),
}

impl GroupPlan {
    fn lost(&self) -> &[usize] {
        match self {
            GroupPlan::Keep => &[],
            GroupPlan::Retry(l) | GroupPlan::Degraded(l) | GroupPlan::Abort(l) => l,
        }
    }
}

/// Timing of a lane that lost members: the survivors' timeout (one link
/// latency) plus an optional recovery gather, attributed to the phase
/// the exchange mode makes legible (RS lanes surface the timeout as
/// reduce-scatter time — the seed's convention; full-gather lanes have
/// no RS phase so everything books as gather time).
fn lossy_timing(exchange: GroupExchange, latency: f64, gather_s: f64) -> ExchangeTiming {
    match exchange {
        GroupExchange::ReduceScatter => ExchangeTiming {
            reduce_scatter_s: latency,
            all_gather_s: gather_s,
        },
        GroupExchange::FullGather => ExchangeTiming {
            reduce_scatter_s: 0.0,
            all_gather_s: latency + gather_s,
        },
    }
}

/// Per-survivor links for a degraded recovery gather: degradation
/// multipliers persist, loss outcomes are not re-rolled (stops the
/// cascade). Empty input (faults off) stays empty.
fn survivor_links(links: &[LinkFault], lost: &[usize]) -> Vec<LinkFault> {
    links
        .iter()
        .enumerate()
        .filter(|(i, _)| !lost.contains(i))
        .map(|(_, f)| f.degraded_only())
        .collect()
}

/// One group's exchange + averaging — the parallel lane body, over the
/// exclusive member views `exec::par_disjoint_map` hands out. `plan`
/// carries the pre-drawn loss plan and `links` the members' pre-drawn
/// link faults (empty when link faults are off — the bookers then take
/// their exact legacy paths); `stripe_par` fans owner stripes across the
/// pool when the round's group count underfills it. `policy` selects the
/// robust center (`Mean` is the exact legacy path); `want_scores`
/// additionally returns each member's distance to the center for the
/// reputation ledger. Lossy groups yield no reputation evidence — their
/// members are already penalized through the fault path.
#[allow(clippy::too_many_arguments)]
fn exchange_lane(
    views: &mut [&mut PeerState],
    plan: &GroupPlan,
    links: &[LinkFault],
    exchange: GroupExchange,
    bytes: u64,
    fabric: &Fabric,
    stripe_par: bool,
    policy: RobustPolicy,
    want_scores: bool,
) -> (ExchangeTiming, Option<GroupScores>) {
    match (exchange, plan) {
        (GroupExchange::ReduceScatter, GroupPlan::Keep) => {
            let timing = if links.is_empty() {
                book_reduce_scatter_fabric(views.len(), bytes, fabric)
            } else {
                book_reduce_scatter_faulty(links, bytes, fabric)
            };
            let scores =
                robust_average_views_chunked(views, stripe_par, policy, want_scores);
            (timing, scores)
        }
        (GroupExchange::FullGather, GroupPlan::Keep) => {
            let t = if links.is_empty() {
                book_group_exchange_fabric(
                    views.len(),
                    bytes,
                    GroupExchange::FullGather,
                    fabric,
                )
            } else {
                book_full_gather_faulty(links, bytes, fabric)
            };
            let scores = robust_average_views(views, policy, want_scores);
            (ExchangeTiming { reduce_scatter_s: 0.0, all_gather_s: t }, scores)
        }
        (_, GroupPlan::Retry(_)) | (_, GroupPlan::Abort(_)) => {
            // members vanished but nobody averages: the survivors time
            // out on the missing traffic (one link latency) and either
            // defer to the next round's matchmaking (Retry) or sit the
            // round out below quorum (Abort) — no recovery bytes
            (lossy_timing(exchange, fabric.latency, 0.0), None)
        }
        (_, GroupPlan::Degraded(lost)) => {
            // members vanished: the survivors time out on the missing
            // traffic (one link latency) and redo the exchange as a
            // full gather among themselves; the lost peers go stale
            let mut survivors: Vec<&mut PeerState> = views
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| !lost.contains(i))
                .map(|(_, v)| &mut **v)
                .collect();
            let t = if links.is_empty() {
                book_group_exchange_fabric(
                    survivors.len(),
                    bytes,
                    GroupExchange::FullGather,
                    fabric,
                )
            } else {
                book_full_gather_faulty(&survivor_links(links, lost), bytes, fabric)
            };
            robust_average_views(&mut survivors, policy, false);
            (lossy_timing(exchange, fabric.latency, t), None)
        }
    }
}

/// Serial-reference twin of [`exchange_lane`] (keeps the Pallas
/// `group_mean` dispatch available on the mean-policy full-gather path;
/// chunk-owned and robust averaging are native-only).
#[allow(clippy::too_many_arguments)]
fn exchange_lane_serial(
    states: &mut [PeerState],
    members: &[usize],
    plan: &GroupPlan,
    links: &[LinkFault],
    exchange: GroupExchange,
    bytes: u64,
    ctx: &mut AggCtx<'_>,
    policy: RobustPolicy,
    want_scores: bool,
) -> Result<(ExchangeTiming, Option<GroupScores>)> {
    Ok(match (exchange, plan) {
        (GroupExchange::ReduceScatter, GroupPlan::Keep) => {
            let timing = if links.is_empty() {
                book_reduce_scatter_fabric(members.len(), bytes, ctx.fabric)
            } else {
                book_reduce_scatter_faulty(links, bytes, ctx.fabric)
            };
            let scores =
                robust_average_group_chunked(states, members, policy, want_scores);
            (timing, scores)
        }
        (GroupExchange::FullGather, GroupPlan::Keep) => {
            let t = if links.is_empty() {
                book_group_exchange_mode(
                    members.len(),
                    bytes,
                    GroupExchange::FullGather,
                    ctx,
                )
            } else {
                book_full_gather_faulty(links, bytes, ctx.fabric)
            };
            let scores =
                robust_average_group(states, members, ctx, policy, want_scores)?;
            (ExchangeTiming { reduce_scatter_s: 0.0, all_gather_s: t }, scores)
        }
        (_, GroupPlan::Retry(_)) | (_, GroupPlan::Abort(_)) => {
            (lossy_timing(exchange, ctx.fabric.latency, 0.0), None)
        }
        (_, GroupPlan::Degraded(lost)) => {
            let survivors: Vec<usize> = members
                .iter()
                .enumerate()
                .filter(|(i, _)| !lost.contains(i))
                .map(|(_, &peer)| peer)
                .collect();
            let t = if links.is_empty() {
                book_group_exchange_fabric(
                    survivors.len(),
                    bytes,
                    GroupExchange::FullGather,
                    ctx.fabric,
                )
            } else {
                book_full_gather_faulty(
                    &survivor_links(links, lost),
                    bytes,
                    ctx.fabric,
                )
            };
            robust_average_group_native(states, &survivors, policy, false);
            (lossy_timing(exchange, ctx.fabric.latency, t), None)
        }
    })
}

impl Aggregate for MarAggregator {
    fn name(&self) -> &'static str {
        "marfl"
    }

    fn aggregate(
        &mut self,
        states: &mut [PeerState],
        agg: &[usize],
        ctx: &mut AggCtx<'_>,
    ) -> Result<AggReport> {
        let n = agg.len();
        if n < 2 {
            return Ok(AggReport::default());
        }
        self.iteration += 1;
        let m = self.group_size;
        let d = self.rounds;
        // exact grid when possible (paper's default configuration),
        // otherwise uniform random keys (approximate mode)
        let mut keys = if perfect_grid(n, m, d) {
            grid_keys(n, m, d)
        } else {
            random_keys(n, m, d, ctx.rng)
        };

        let bytes = payload_bytes(states, agg);
        let scope = format!("agg{}", self.iteration);
        let mut groups_formed = 0;
        self.crashed_last.clear();
        let mut fault_totals = FaultCounters::default();
        // chunk owners that dropped this iteration: stale state, excluded
        // from every subsequent round's matchmaking. Reputation bans
        // (decided at the end of *previous* iterations — the pipelined
        // control plane fixes membership before scores exist) start a
        // peer out dead for the whole iteration.
        let mut alive: Vec<bool> = vec![true; n];
        if let Some(rep) = self.rep.as_mut() {
            for (pos, &peer) in agg.iter().enumerate() {
                if rep.is_banned(peer) {
                    alive[pos] = false;
                    // this ban shaped membership — it counts as an
                    // effective flag in the precision/recall scorecard
                    rep.note_gated(peer);
                }
            }
        }
        let policy = self.robust;
        let want_scores = self.rep.is_some();
        // the Pallas artifact path runs through the (non-Sync-friendly)
        // runtime dispatch; keep it on the serial reference engine
        let run_parallel = self.parallel
            && !(ctx.runtime.is_some()
                && crate::aggregation::pjrt_group_mean_enabled());
        // closed-form cross-check: chunk-owned phases must book exactly
        // 2(k−1)·bytes per successful group (verified in debug builds)
        let phase_base = ctx.fabric.ledger().snapshot();
        let mut expected_phase_bytes = 0u64;
        let mut rs_fallbacks = 0usize;
        let mut rs_retries = 0usize;
        // owner-drop retries remaining this iteration (schedule state,
        // consumed serially as drops are drawn)
        let mut retries_left = self.rs_retry_budget;
        // Pipelined control plane: a round's chunk indices and owner-drop
        // plan are schedule state fully determined by its *membership*
        // (known the moment matchmaking returns), so round g+1's DHT
        // matchmaking proceeds concurrently with round g's group
        // averaging. Only round 0's matchmaking is exposed on the clock;
        // every later pass hides under the previous round's exchange and
        // extends it only by its overhang (SimClock::pipelined_two_phase).
        let (mut groups, mm0) =
            self.matchmake_timed(agg, &keys, &alive, 0, &scope, ctx.fabric);
        // empty data lanes: advances by mm0 exactly, attributed exposed
        ctx.clock.pipelined_two_phase(mm0, std::iter::empty());
        self.trace_ev(
            ctx.clock.now(),
            EventKind::Matchmaking {
                round: 0,
                control_s: mm0,
                hidden: false,
                groups: groups.len() as u64,
            },
        );
        let legacy_drops_on =
            self.exchange == GroupExchange::ReduceScatter && self.rs_drop > 0.0;
        let crash_on = ctx.faults.crash_prob > 0.0;
        let link_faults_on = ctx.faults.link_faults_enabled();
        for g in 0..d {
            // loss plan: drawn serially before fanning out (it is
            // schedule state, like batch cursors), so parallel lanes stay
            // bit-identical to the serial reference. Nothing is drawn
            // while every knob is off; the legacy victim draw comes first
            // with the seed's exact gating and order, so rs_drop alone
            // (faults off) reproduces the seed bit for bit.
            let exchange = self.exchange;
            let mut plans: Vec<GroupPlan> = Vec::with_capacity(groups.len());
            let mut link_plans: Vec<Vec<LinkFault>> =
                Vec::with_capacity(groups.len());
            // plan drawing is a serial schedule phase: the clock has not
            // advanced for this round yet, so every plan event lands at
            // the same simulated instant in both engines
            let t_plan = ctx.clock.now();
            for (gi, group) in groups.iter().enumerate() {
                let k = group.len();
                // (1) legacy chunk-owner drop (seed-exact draw order)
                let legacy_victim = if legacy_drops_on
                    && k >= 2
                    && ctx.rng.chance(self.rs_drop)
                {
                    Some(ctx.rng.below(k))
                } else {
                    None
                };
                // (2) mid-exchange crashes
                let mut crashed: Vec<usize> = Vec::new();
                if crash_on && k >= 2 {
                    for chunk in 0..k {
                        if ctx.rng.chance(ctx.faults.crash_prob) {
                            crashed.push(chunk);
                        }
                    }
                }
                // (3) per-member link faults (crashed members draw
                // nothing — their traffic never happens)
                let mut links: Vec<LinkFault> = Vec::new();
                if link_faults_on && k >= 2 {
                    // messages per destination; with no LinkState this
                    // delegates to the seed's draw_link(msgs_per_dst·(k−1))
                    // bit for bit; with one present a member's retries
                    // observe the per-destination Gilbert–Elliott chains
                    let msgs_per_dst = match exchange {
                        GroupExchange::ReduceScatter => 2,
                        GroupExchange::FullGather => 1,
                    };
                    links = (0..k)
                        .map(|chunk| {
                            if crashed.contains(&chunk) {
                                LinkFault::CLEAN
                            } else {
                                let dsts: Vec<usize> = group
                                    .iter()
                                    .enumerate()
                                    .filter(|&(c, _)| c != chunk)
                                    .map(|(_, &pos)| agg[pos])
                                    .collect();
                                ctx.faults.draw_member(
                                    agg[group[chunk]],
                                    &dsts,
                                    msgs_per_dst,
                                    ctx.links.as_deref_mut(),
                                    ctx.rng,
                                )
                            }
                        })
                        .collect();
                    for f in &links {
                        fault_totals.absorb(f);
                    }
                    let retries: u64 = links.iter().map(|f| f.retries).sum();
                    let timeouts: u64 = links.iter().map(|f| f.timeouts).sum();
                    if retries + timeouts > 0 {
                        self.trace_ev(
                            t_plan,
                            EventKind::FaultRetries {
                                round: g as u64,
                                group: gi as u64,
                                retries,
                                timeouts,
                            },
                        );
                    }
                }
                fault_totals.crashes += crashed.len() as u64;
                for &chunk in &crashed {
                    self.crashed_last.push(agg[group[chunk]]);
                    self.trace_ev(
                        t_plan,
                        EventKind::Crash { peer: agg[group[chunk]] as u64 },
                    );
                }
                // (4) the lost set: crashed peers, peers whose messages
                // exhausted the retry budget, and the legacy victim
                let fault_lost_any = !crashed.is_empty()
                    || links.iter().any(LinkFault::lost);
                let mut lost = crashed;
                for (chunk, f) in links.iter().enumerate() {
                    if f.lost() && !lost.contains(&chunk) {
                        lost.push(chunk);
                    }
                }
                if let Some(v) = legacy_victim {
                    if !lost.contains(&v) {
                        lost.push(v);
                    }
                }
                lost.sort_unstable();
                // (5) classify — the legacy-only case reproduces the
                // seed's Retry/Fallback decision exactly
                let plan = if lost.is_empty() {
                    GroupPlan::Keep
                } else if !fault_lost_any {
                    if retries_left > 0 && g + 1 < d {
                        retries_left -= 1;
                        GroupPlan::Retry(lost)
                    } else {
                        GroupPlan::Degraded(lost)
                    }
                } else if exchange == GroupExchange::ReduceScatter
                    && retries_left > 0
                    && g + 1 < d
                {
                    retries_left -= 1;
                    GroupPlan::Retry(lost)
                } else if k - lost.len() >= ctx.faults.quorum_min.max(2) {
                    GroupPlan::Degraded(lost)
                } else {
                    GroupPlan::Abort(lost)
                };
                // key/alive bookkeeping — membership plus the pre-drawn
                // plan determine it, which is exactly what lets the next
                // matchmaking pass start before the exchange finishes
                for (chunk, &pos) in group.iter().enumerate() {
                    if plan.lost().contains(&chunk) {
                        // a lost member sits out the rest of the
                        // iteration (stale key, no announcements)
                        alive[pos] = false;
                    } else {
                        keys[pos].set_chunk(g, chunk);
                    }
                }
                match &plan {
                    GroupPlan::Keep => {
                        if k >= 2 {
                            groups_formed += 1;
                        }
                        if exchange == GroupExchange::ReduceScatter && k >= 2 {
                            // the closed form the faulty RS booker
                            // matches: both phases plus per-member retry
                            // surcharges at the balanced chunk floor
                            expected_phase_bytes += 2 * (k as u64 - 1) * bytes;
                            for f in &links {
                                expected_phase_bytes +=
                                    f.retries * (bytes / k as u64);
                            }
                        }
                    }
                    GroupPlan::Degraded(lost) => {
                        if legacy_victim.is_some() {
                            rs_fallbacks += 1;
                            self.trace_ev(
                                t_plan,
                                EventKind::OwnerDropFallback {
                                    round: g as u64,
                                    group: gi as u64,
                                },
                            );
                        }
                        if fault_lost_any {
                            fault_totals.quorum_degraded_rounds += 1;
                            self.trace_ev(
                                t_plan,
                                EventKind::QuorumDegraded {
                                    round: g as u64,
                                    group: gi as u64,
                                    lost: lost.len() as u64,
                                },
                            );
                        }
                        if k - lost.len() >= 2 {
                            groups_formed += 1;
                        }
                    }
                    // deferred: survivors average nothing this round and
                    // re-form next round instead
                    GroupPlan::Retry(_) => {
                        rs_retries += 1;
                        self.trace_ev(
                            t_plan,
                            EventKind::RsRetry { round: g as u64, group: gi as u64 },
                        );
                    }
                    GroupPlan::Abort(lost) => self.trace_ev(
                        t_plan,
                        EventKind::GroupAbort {
                            round: g as u64,
                            group: gi as u64,
                            lost: lost.len() as u64,
                        },
                    ),
                }
                plans.push(plan);
                link_plans.push(links);
            }
            // round g+1's matchmaking — control plane, overlapped with
            // this round's exchange at the clock boundary below
            let (next_groups, mm_next) = if g + 1 < d {
                self.matchmake_timed(agg, &keys, &alive, g + 1, &scope, ctx.fabric)
            } else {
                (Vec::new(), 0.0)
            };

            // positions -> peer indices; groups within a round are
            // disjoint index sets over `states` by construction
            let member_groups: Vec<Vec<usize>> = groups
                .iter()
                .map(|grp| grp.iter().map(|&pos| agg[pos]).collect())
                .collect();
            // when a round forms fewer groups than the pool has workers,
            // chunk-owned averaging recovers utilization by striping
            // owners across the idle workers (bit-identical either way)
            let stripe_par =
                run_parallel && member_groups.len() * 2 <= exec::threads();
            let lane_out: Vec<(ExchangeTiming, Option<GroupScores>)> =
                if run_parallel {
                    // every group books its exchange and averages
                    // concurrently; lane order (and thus the clock) matches
                    // the serial path because results come back in group order
                    let fabric = ctx.fabric;
                    let plans_ref = &plans;
                    let links_ref = &link_plans;
                    exec::par_disjoint_map(states, &member_groups, |gi, views| {
                        exchange_lane(
                            views,
                            &plans_ref[gi],
                            &links_ref[gi],
                            exchange,
                            bytes,
                            fabric,
                            stripe_par,
                            policy,
                            want_scores,
                        )
                    })?
                } else {
                    let mut lane_out = Vec::with_capacity(member_groups.len());
                    for (gi, members) in member_groups.iter().enumerate() {
                        lane_out.push(exchange_lane_serial(
                            states,
                            members,
                            &plans[gi],
                            &link_plans[gi],
                            exchange,
                            bytes,
                            ctx,
                            policy,
                            want_scores,
                        )?);
                    }
                    lane_out
                };
            // fold this round's outlier evidence in group order (serial,
            // deterministic regardless of lane scheduling)
            if let Some(rep) = self.rep.as_mut() {
                for (gi, (_, scores)) in lane_out.iter().enumerate() {
                    if let Some(sc) = scores {
                        rep.observe_group(&member_groups[gi], sc);
                    }
                }
            }
            // groups communicate concurrently; within a group the
            // all-gather starts only once its reduction is done; the next
            // round's matchmaking hides under the exchange. Causality
            // exception: an owner drop is only *observable* mid-exchange,
            // and the next pass's announcer set reacts to it — so a round
            // that lost an owner books its matchmaking sequentially
            // (survivors time out first, then re-announce) instead of
            // overlapped.
            let lanes = lane_out
                .iter()
                .map(|(t, _)| (t.reduce_scatter_s, t.all_gather_s));
            let all_keep = plans.iter().all(|p| *p == GroupPlan::Keep);
            if all_keep {
                ctx.clock.pipelined_two_phase(mm_next, lanes);
            } else {
                ctx.clock.pipelined_two_phase(0.0, lanes);
                // sequential pass: fully exposed on the clock
                ctx.clock.pipelined_two_phase(mm_next, std::iter::empty());
            }
            // exchange span: the gating (slowest) lane per phase — the
            // lane outputs are bit-identical between engines, so the
            // recorded span is too
            let rs_s = lane_out
                .iter()
                .map(|(t, _)| t.reduce_scatter_s)
                .fold(0.0f64, f64::max);
            let ag_s = lane_out
                .iter()
                .map(|(t, _)| t.all_gather_s)
                .fold(0.0f64, f64::max);
            self.trace_ev(
                ctx.clock.now(),
                EventKind::Exchange {
                    round: g as u64,
                    groups: member_groups.len() as u64,
                    rs_s,
                    ag_s,
                },
            );
            if g + 1 < d {
                self.trace_ev(
                    ctx.clock.now(),
                    EventKind::Matchmaking {
                        round: g as u64 + 1,
                        control_s: mm_next,
                        hidden: all_keep,
                        groups: next_groups.len() as u64,
                    },
                );
            }
            groups = next_groups;
        }
        // chunk-owned booking is exact: across the iteration the two wire
        // phases together move 2(k−1)·bytes per successful group — the
        // 2(M−1)/M state transfers per member the ablation advertises
        if self.exchange == GroupExchange::ReduceScatter {
            let delta = ctx.fabric.ledger().snapshot().since(&phase_base);
            debug_assert_eq!(
                delta.rs_bytes + delta.ag_bytes,
                expected_phase_bytes,
                "chunk-owned booking must match the closed form"
            );
        }
        // iteration boundary: EWMA-fold the staged observations, expire
        // old bans, hand out new ones (bounded; see `Reputation`)
        let flagged_peers = match self.rep.as_mut() {
            Some(rep) => rep.fold_iteration(),
            None => 0,
        };
        if self.trace.is_some() {
            let events =
                self.rep.as_mut().map(Reputation::drain_events).unwrap_or_default();
            let t_fold = ctx.clock.now();
            for e in events {
                let kind = match e {
                    RepEvent::Ban(p) => EventKind::Ban { peer: p as u64 },
                    RepEvent::Parole(p) => EventKind::Parole { peer: p as u64 },
                    RepEvent::Reban(p) => EventKind::Reban { peer: p as u64 },
                };
                self.trace_ev(t_fold, kind);
            }
        }
        Ok(AggReport {
            rounds: d,
            groups: groups_formed,
            rs_fallbacks,
            rs_retries,
            flagged_peers,
            faults: fault_totals,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aggregation::test_support::*;
    use crate::aggregation::mean_of;
    use crate::metrics::CommLedger;

    /// Build a MarAggregator sharing the TestCtx ledger (as the Trainer
    /// does), so control and data traffic land on the same counters.
    fn mar_on(tc: &TestCtx, n: usize, m: usize, g: usize) -> MarAggregator {
        MarAggregator::new(n, m, g, tc.ledger.clone(), 7)
    }

    fn mar(n: usize, m: usize, g: usize) -> (MarAggregator, Arc<CommLedger>) {
        let ledger = Arc::new(CommLedger::new());
        (MarAggregator::new(n, m, g, ledger.clone(), 7), ledger)
    }

    #[test]
    fn perfect_grid_gives_exact_global_average() {
        // 8 = 2^3
        let n = 8;
        let mut states = random_states(n, 64, 20);
        let agg: Vec<usize> = (0..n).collect();
        let (want_t, want_m) = mean_of(&states, &agg);
        let (mut mar, _) = mar(n, 2, 3);
        let mut tc = TestCtx::new(64);
        let mut ctx = tc.ctx();
        let rep = mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        assert_eq!(rep.rounds, 3);
        for s in &states {
            crate::testing::assert_allclose(&s.theta, &want_t, 1e-5, 1e-6);
            crate::testing::assert_allclose(&s.momentum, &want_m, 1e-5, 1e-6);
        }
    }

    #[test]
    fn perfect_grid_27_peers() {
        let n = 27;
        let mut states = random_states(n, 16, 21);
        let agg: Vec<usize> = (0..n).collect();
        let (want_t, _) = mean_of(&states, &agg);
        let (mut mar, _) = mar(n, 3, 3);
        let mut tc = TestCtx::new(16);
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        for s in &states {
            crate::testing::assert_allclose(&s.theta, &want_t, 1e-5, 1e-6);
        }
    }

    #[test]
    fn transfer_count_is_n_g_m_minus_one_on_grid() {
        let n = 27;
        let mut states = random_states(n, 8, 22);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(8);
        let mut mar = mar_on(&tc, n, 3, 3);
        let before = tc.ledger.snapshot();
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        // exact grid: every round has n/m groups of m; per group m(m-1)
        // transfers -> total n*g*(m-1)
        let delta = tc.ledger.snapshot().since(&before);
        assert_eq!(delta.data_msgs as usize, n * 3 * 2);
    }

    #[test]
    fn approximate_mode_reduces_distortion() {
        // 20 peers, M=3, G=3: no perfect grid; one aggregate call must
        // strictly shrink the average distance to the global mean
        let n = 20;
        let mut states = random_states(n, 32, 23);
        let agg: Vec<usize> = (0..n).collect();
        let (want_t, _) = mean_of(&states, &agg);
        let before: f64 = states
            .iter()
            .map(|s| crate::util::mse(&s.theta, &want_t))
            .sum::<f64>();
        let (mut mar, _) = mar(n, 3, 3);
        let mut tc = TestCtx::new(32);
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let after: f64 = states
            .iter()
            .map(|s| crate::util::mse(&s.theta, &want_t))
            .sum::<f64>();
        assert!(
            after < before * 0.2,
            "distortion barely reduced: {before} -> {after}"
        );
        // mean must be preserved by averaging (up to fp noise)
        let (new_mean, _) = mean_of(&states, &agg);
        crate::testing::assert_allclose(&new_mean, &want_t, 1e-4, 1e-5);
    }

    #[test]
    fn aggregates_only_the_aggregator_subset() {
        let n = 10;
        let mut states = random_states(n, 8, 24);
        let before9 = states[9].theta.clone();
        let agg: Vec<usize> = (0..8).collect(); // 8 = 2^3 grid
        let (mut mar, _) = mar(n, 2, 3);
        let mut tc = TestCtx::new(8);
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        assert_eq!(states[9].theta, before9);
    }

    #[test]
    fn no_revisit_within_iteration() {
        // on a perfect grid, track groupmates across rounds: no pair may
        // meet twice within one aggregate() call
        let n = 16;
        let m = 4;
        let d = 2;
        let keys = grid_keys(n, m, d);
        let mut met = std::collections::HashSet::new();
        let mut keys = keys;
        for g in 0..d {
            let mut by_key: BTreeMap<String, Vec<usize>> = BTreeMap::new();
            for (pos, k) in keys.iter().enumerate() {
                by_key.entry(k.reduced(g)).or_default().push(pos);
            }
            for (_, group) in by_key {
                for i in 0..group.len() {
                    for j in i + 1..group.len() {
                        let pair = (group[i], group[j]);
                        assert!(
                            met.insert(pair),
                            "pair {pair:?} met twice (round {g})"
                        );
                    }
                }
                for (chunk, &pos) in group.iter().enumerate() {
                    keys[pos].set_chunk(g, chunk);
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_cuts_group_traffic() {
        let n = 27;
        let mut tc = TestCtx::new(1024);
        let run = |exchange, tc: &mut TestCtx| {
            let mut states = random_states(n, 1024, 26);
            let agg: Vec<usize> = (0..n).collect();
            let mut mar = MarAggregator::with_options(
                n,
                3,
                3,
                tc.ledger.clone(),
                7,
                AggOptions { exchange, ..AggOptions::default() },
            );
            tc.ledger.reset();
            let mut ctx = tc.ctx();
            mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
            // exactness must be identical in both modes
            let (mean, _) = mean_of(&states, &agg);
            for s in &states {
                crate::testing::assert_allclose(&s.theta, &mean, 1e-4, 1e-5);
            }
            tc.ledger.snapshot().data_bytes
        };
        let full = run(crate::aggregation::GroupExchange::FullGather, &mut tc);
        let rs = run(crate::aggregation::GroupExchange::ReduceScatter, &mut tc);
        // M=3: reduce-scatter moves 2(k-1)/k = 4/3 chunks vs (k-1) = 2
        // full states per member -> ratio 2/(4/3) = 1.5
        let ratio = full as f64 / rs as f64;
        assert!((1.3..1.7).contains(&ratio), "RS saving ratio {ratio}");
    }

    #[test]
    fn reduce_scatter_books_closed_form_phase_bytes() {
        // perfect 3^3 grid: every round forms 9 groups of M=3; each group
        // books exactly (M−1)·bytes per phase
        let n = 27;
        let p = 1024;
        let mut tc = TestCtx::new(p);
        let mut states = random_states(n, p, 27);
        let agg: Vec<usize> = (0..n).collect();
        let mut mar = MarAggregator::with_options(
            n,
            3,
            3,
            tc.ledger.clone(),
            7,
            AggOptions {
                exchange: crate::aggregation::GroupExchange::ReduceScatter,
                ..AggOptions::default()
            },
        );
        tc.ledger.reset();
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let s = tc.ledger.snapshot();
        let bytes = 2 * p as u64 * 4;
        let want = 3u64 * 9 * 2 * (3 - 1) * bytes;
        assert_eq!(s.rs_bytes + s.ag_bytes, want);
        assert_eq!(s.rs_bytes, s.ag_bytes, "phases move the same volume");
        assert_eq!(
            s.data_bytes, want,
            "RS-mode data traffic is exactly the two phases"
        );
        // k(k−1) chunk messages per group per phase
        assert_eq!(s.rs_msgs, 3 * 9 * 3 * 2);
        assert_eq!(s.ag_msgs, 3 * 9 * 3 * 2);
        // per-member closed form: G · 2(M−1)/M state transfers each
        assert_eq!(s.rs_bytes + s.ag_bytes, n as u64 * 3 * 2 * 2 * bytes / 3);
        // two-phase clock modeling attributed time to both phases
        let (rs_t, ag_t) = tc.clock.phase_times();
        assert!(rs_t > 0.0 && ag_t > 0.0);
        assert!(rs_t + ag_t <= tc.clock.now());
    }

    #[test]
    fn full_gather_books_no_phase_traffic() {
        let n = 8;
        let mut tc = TestCtx::new(64);
        let mut states = random_states(n, 64, 28);
        let agg: Vec<usize> = (0..n).collect();
        let mut mar = mar_on(&tc, n, 2, 3);
        tc.ledger.reset();
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let s = tc.ledger.snapshot();
        assert!(s.data_bytes > 0);
        assert_eq!(s.rs_bytes, 0);
        assert_eq!(s.ag_bytes, 0);
    }

    #[test]
    fn control_plane_books_bytes_but_far_less_than_data() {
        // realistic model size (the cnn task's P_pad): control traffic is
        // size-independent, so the paper's "negligible" claim is about
        // real models, not toy vectors
        let n = 27;
        let p = 18432;
        let mut states = random_states(n, p, 25);
        let agg: Vec<usize> = (0..n).collect();
        let mut tc = TestCtx::new(p);
        let mut mar = mar_on(&tc, n, 3, 3);
        tc.ledger.reset(); // drop DHT join traffic; measure one iteration
        let mut ctx = tc.ctx();
        mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        let s = tc.ledger.snapshot();
        assert!(s.control_bytes > 0, "no control traffic booked");
        assert!(
            s.control_bytes * 10 < s.data_bytes,
            "control plane ({}) not negligible vs data ({})",
            s.control_bytes,
            s.data_bytes
        );
    }
}
