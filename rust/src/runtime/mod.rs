//! PJRT runtime: loads AOT HLO-text artifacts and executes them on the
//! training path. This is the ONLY place model compute happens at run
//! time — Python is never on the request path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` (cached per entry
//! point) → `execute`.

pub mod literal;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::models::{ArtifactMeta, ModelMeta};
use literal::{lit_f32, lit_i32, to_f32_vec};

/// Compiled-executable cache keyed by entry-point name.
pub struct Runtime {
    client: xla::PjRtClient,
    pub meta: ArtifactMeta,
    exes: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// executions per entry point (perf accounting)
    calls: RefCell<HashMap<String, u64>>,
}

/// Result of one local training / KD step.
#[derive(Clone, Debug)]
pub struct StepOut {
    pub theta: Vec<f32>,
    pub momentum: Vec<f32>,
    pub loss: f32,
}

impl Runtime {
    /// Create a CPU PJRT client over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let meta = ArtifactMeta::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime {
            client,
            meta,
            exes: RefCell::new(HashMap::new()),
            calls: RefCell::new(HashMap::new()),
        })
    }

    /// Load the shared initial parameters for `model` (paper: every peer
    /// starts from the same randomly initialized θ⁰).
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let m = self.meta.model(model)?;
        let path = self.meta.artifact_path(&m.init_file);
        let theta = crate::util::read_f32_le(&path)?;
        anyhow::ensure!(
            theta.len() == m.padded_len,
            "{path:?}: expected {} f32, got {}",
            m.padded_len,
            theta.len()
        );
        Ok(theta)
    }

    fn execute(
        &self,
        entry: &str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(entry)?;
        *self.calls.borrow_mut().entry(entry.to_string()).or_insert(0) += 1;
        let exes = self.exes.borrow();
        let exe = exes.get(entry).unwrap();
        let result = exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("execute {entry}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("sync {entry}"))?;
        // every entry point returns a tuple (aot.py lowers return_tuple=True)
        out.to_tuple().with_context(|| format!("untuple {entry}"))
    }

    fn ensure_compiled(&self, entry: &str) -> Result<()> {
        if self.exes.borrow().contains_key(entry) {
            return Ok(());
        }
        let path = self.meta.artifact_path(&format!("{entry}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse {path:?} — run `make artifacts`"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {entry}"))?;
        self.exes.borrow_mut().insert(entry.to_string(), exe);
        Ok(())
    }

    /// Pre-compile a set of entry points (avoids first-use jitter in
    /// benches).
    pub fn warmup(&self, entries: &[String]) -> Result<()> {
        for e in entries {
            self.ensure_compiled(e)?;
        }
        Ok(())
    }

    /// Per-entry execution counts (perf diagnostics).
    pub fn call_counts(&self) -> HashMap<String, u64> {
        self.calls.borrow().clone()
    }

    // -----------------------------------------------------------------
    // Typed entry points (flat-parameter ABI)
    // -----------------------------------------------------------------

    /// One local momentum-SGD step over a batch.
    pub fn train_step(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        debug_assert_eq!(theta.len(), m.padded_len);
        debug_assert_eq!(x.len(), m.batch * m.input_elems());
        debug_assert_eq!(y.len(), m.batch);
        let mut dims = vec![m.batch];
        dims.extend(&m.input_shape);
        let args = [
            lit_f32(theta, &[m.padded_len])?,
            lit_f32(momentum, &[m.padded_len])?,
            lit_f32(x, &dims)?,
            lit_i32(y, &[m.batch])?,
            lit_f32(&[eta], &[1])?,
            lit_f32(&[mu], &[1])?,
        ];
        let out = self.execute(&format!("{}_train_step", m.name), &args)?;
        anyhow::ensure!(out.len() == 3, "train_step returned {} leaves", out.len());
        Ok(StepOut {
            theta: to_f32_vec(&out[0])?,
            momentum: to_f32_vec(&out[1])?,
            loss: out[2].to_vec::<f32>()?[0],
        })
    }

    /// One Moshpit-KD student step (Algorithm 2).
    #[allow(clippy::too_many_arguments)]
    pub fn kd_step(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        momentum: &[f32],
        x: &[f32],
        y: &[i32],
        zbar: &[f32],
        lambda: f32,
        eta: f32,
        mu: f32,
    ) -> Result<StepOut> {
        debug_assert_eq!(zbar.len(), m.batch * m.classes);
        let mut dims = vec![m.batch];
        dims.extend(&m.input_shape);
        let args = [
            lit_f32(theta, &[m.padded_len])?,
            lit_f32(momentum, &[m.padded_len])?,
            lit_f32(x, &dims)?,
            lit_i32(y, &[m.batch])?,
            lit_f32(zbar, &[m.batch, m.classes])?,
            lit_f32(&[lambda], &[1])?,
            lit_f32(&[eta], &[1])?,
            lit_f32(&[mu], &[1])?,
        ];
        let out = self.execute(&format!("{}_kd_step", m.name), &args)?;
        anyhow::ensure!(out.len() == 3, "kd_step returned {} leaves", out.len());
        Ok(StepOut {
            theta: to_f32_vec(&out[0])?,
            momentum: to_f32_vec(&out[1])?,
            loss: out[2].to_vec::<f32>()?[0],
        })
    }

    /// Teacher forward pass: logits for one training batch.
    pub fn logits(&self, m: &ModelMeta, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let mut dims = vec![m.batch];
        dims.extend(&m.input_shape);
        let args = [lit_f32(theta, &[m.padded_len])?, lit_f32(x, &dims)?];
        let out = self.execute(&format!("{}_logits", m.name), &args)?;
        to_f32_vec(&out[0])
    }

    /// Evaluate over a full test set (x row-major, len multiple of the
    /// eval chunk). Returns (mean loss, accuracy).
    pub fn evaluate(
        &self,
        m: &ModelMeta,
        theta: &[f32],
        x: &[f32],
        y: &[i32],
    ) -> Result<(f64, f64)> {
        let n = y.len();
        let elems = m.input_elems();
        anyhow::ensure!(
            n % m.eval_chunk == 0,
            "test set size {n} not a multiple of eval chunk {}",
            m.eval_chunk
        );
        let mut dims = vec![m.eval_chunk];
        dims.extend(&m.input_shape);
        let theta_lit = lit_f32(theta, &[m.padded_len])?;
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        for c in 0..n / m.eval_chunk {
            let xs = &x[c * m.eval_chunk * elems..(c + 1) * m.eval_chunk * elems];
            let ys = &y[c * m.eval_chunk..(c + 1) * m.eval_chunk];
            let args = [
                theta_lit.clone(),
                lit_f32(xs, &dims)?,
                lit_i32(ys, &[m.eval_chunk])?,
            ];
            let out = self.execute(&format!("{}_eval", m.name), &args)?;
            loss_sum += out[0].to_vec::<f32>()?[0] as f64;
            correct += out[1].to_vec::<f32>()?[0] as f64;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }

    /// Average `k` stacked flat vectors through the Pallas group-mean
    /// artifact. `stack` is row-major `[k, padded_len]`.
    pub fn group_mean(&self, m: &ModelMeta, stack: &[f32], k: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            self.meta.group_sizes.contains(&k),
            "no group_mean artifact for k={k} (have {:?})",
            self.meta.group_sizes
        );
        debug_assert_eq!(stack.len(), k * m.padded_len);
        let args = [lit_f32(stack, &[k, m.padded_len])?];
        let out = self.execute(&format!("group_mean_{}_{k}", m.name), &args)?;
        to_f32_vec(&out[0])
    }
}

#[cfg(test)]
mod tests {
    // Runtime execution tests live in rust/tests/runtime_integration.rs —
    // they require artifacts (`make artifacts`) and a PJRT client. Unit
    // tests here cover only client-free logic.
    use super::*;

    #[test]
    fn step_out_is_cloneable_value_type() {
        let s = StepOut { theta: vec![1.0], momentum: vec![0.0], loss: 0.5 };
        let t = s.clone();
        assert_eq!(t.loss, 0.5);
    }
}
