//! # MAR-FL — Moshpit All-Reduce Federated Learning
//!
//! A communication-efficient peer-to-peer federated learning system,
//! reproducing Mulitze, Woisetschläger & Jacobsen, *"MAR-FL: A Communication
//! Efficient Peer-to-Peer Federated Learning System"* (NeurIPS 2025 AI4NextG).
//!
//! The crate is the Layer-3 **coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (fused softmax-XENT, damped-momentum update,
//!   group-mean aggregation) authored in `python/compile/kernels/`.
//! * **L2** — JAX model definitions (`python/compile/model.py`) lowered
//!   once, ahead of time, to HLO text in `artifacts/`.
//! * **L3** — this crate: the simulated P2P fabric (Kademlia DHT +
//!   bandwidth-accounted network), the MAR group-formation coordinator,
//!   the aggregation strategies (Moshpit, Ring/RDFL, All-to-All/AR-FL,
//!   client-server FedAvg), Moshpit-KD, decentralized DP, and the
//!   experiment/bench harnesses. Python never runs on the training path;
//!   local peer compute executes through PJRT (`runtime`).
//!
//! Start with [`fl::Trainer`] (end-to-end loop) or the `marfl` CLI.

pub mod aggregation;
pub mod attack;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dht;
pub mod dp;
pub mod exec;
pub mod fl;
pub mod kd;
pub mod metrics;
pub mod models;
pub mod net;
pub mod params;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod testing;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
