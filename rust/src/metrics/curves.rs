//! Training curves: (iteration, cumulative communication, loss, accuracy)
//! series — the x/y data of every figure in the paper.

use super::ledger::CommSnapshot;

/// One evaluation point (the paper evaluates every 5th FL iteration).
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub iteration: usize,
    /// cumulative data-plane bytes when this point was taken
    pub data_bytes: u64,
    /// cumulative control-plane bytes
    pub control_bytes: u64,
    pub loss: f64,
    pub accuracy: f64,
    /// simulated wall-clock seconds (net::SimClock)
    pub sim_time_s: f64,
}

/// A labelled training curve for one technique/configuration.
#[derive(Clone, Debug, Default)]
pub struct TrainCurve {
    pub label: String,
    pub points: Vec<CurvePoint>,
}

impl TrainCurve {
    pub fn new(label: impl Into<String>) -> Self {
        TrainCurve { label: label.into(), points: Vec::new() }
    }

    pub fn push(
        &mut self,
        iteration: usize,
        comm: CommSnapshot,
        loss: f64,
        accuracy: f64,
        sim_time_s: f64,
    ) {
        self.points.push(CurvePoint {
            iteration,
            data_bytes: comm.data_bytes,
            control_bytes: comm.control_bytes,
            loss,
            accuracy,
            sim_time_s,
        });
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.points.last().map(|p| p.accuracy)
    }

    pub fn best_accuracy(&self) -> Option<f64> {
        self.points.iter().map(|p| p.accuracy).fold(None, |acc, a| {
            Some(acc.map_or(a, |b: f64| b.max(a)))
        })
    }

    /// Cumulative data-plane bytes at the first point reaching `target`
    /// accuracy — the paper's "communication to reach X% accuracy" metric
    /// (Figures 2 and 9). `None` if the curve never reaches the target.
    pub fn bytes_to_accuracy(&self, target: f64) -> Option<u64> {
        self.points
            .iter()
            .find(|p| p.accuracy >= target)
            .map(|p| p.data_bytes)
    }

    /// Iterations to reach `target` accuracy.
    pub fn iterations_to_accuracy(&self, target: f64) -> Option<usize> {
        self.points
            .iter()
            .find(|p| p.accuracy >= target)
            .map(|p| p.iteration)
    }

    /// CSV rows (header + data) for this curve.
    pub fn csv_rows(&self) -> Vec<Vec<String>> {
        let mut rows = vec![vec![
            "label".into(),
            "iteration".into(),
            "data_bytes".into(),
            "control_bytes".into(),
            "loss".into(),
            "accuracy".into(),
            "sim_time_s".into(),
        ]];
        for p in &self.points {
            rows.push(vec![
                self.label.clone(),
                p.iteration.to_string(),
                p.data_bytes.to_string(),
                p.control_bytes.to_string(),
                format!("{:.6}", p.loss),
                format!("{:.6}", p.accuracy),
                format!("{:.3}", p.sim_time_s),
            ]);
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve() -> TrainCurve {
        let mut c = TrainCurve::new("marfl");
        for (i, (bytes, acc)) in
            [(100u64, 0.2), (200, 0.5), (300, 0.8), (400, 0.85)].iter().enumerate()
        {
            c.push(
                i * 5,
                CommSnapshot { data_bytes: *bytes, ..Default::default() },
                1.0 - acc,
                *acc,
                i as f64,
            );
        }
        c
    }

    #[test]
    fn bytes_to_accuracy_finds_first_crossing() {
        let c = curve();
        assert_eq!(c.bytes_to_accuracy(0.5), Some(200));
        assert_eq!(c.bytes_to_accuracy(0.79), Some(300));
        assert_eq!(c.bytes_to_accuracy(0.99), None);
    }

    #[test]
    fn iterations_to_accuracy() {
        let c = curve();
        assert_eq!(c.iterations_to_accuracy(0.5), Some(5));
    }

    #[test]
    fn best_and_final() {
        let c = curve();
        assert_eq!(c.final_accuracy(), Some(0.85));
        assert_eq!(c.best_accuracy(), Some(0.85));
    }

    #[test]
    fn csv_has_header_plus_rows() {
        let rows = curve().csv_rows();
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[0][0], "label");
        assert_eq!(rows[1][0], "marfl");
    }
}
