//! Moshpit-KD (paper §2.2, Algorithms 2 & 3).
//!
//! During the first K FL iterations, each MKD round `g`:
//!
//! 1. forms candidate-teacher groups with the same DHT matchmaking MAR
//!    uses (`MarAggregator::form_groups_once_timed`), exchanging *models*
//!    within each group (θ only — the extra per-iteration load Figure 2
//!    charges);
//! 2. each student rates every candidate teacher by the KL divergence
//!    between their softened output distributions on the student's own
//!    local batch (Algorithm 3) and keeps the top-ℓ (ρ_ℓ = 0.4) — the
//!    selective-sharing defence against non-iid teacher noise (Shao et
//!    al. 2024);
//! 3. the student distills from the averaged top-ℓ ensemble logits over E
//!    local epochs with loss L = (1−λ)·CE + λ·τ²·KL, λ = max(0, 1−(t−1)/K)
//!    decaying linearly so MKD hands over to plain MAR training.
//!
//! Execution: the engine runs the whole pass *in parallel* on the `exec`
//! pool. Round-start teacher models are snapshot as shared [`Theta`]
//! handles (zero copies — the copy-on-write storage makes a snapshot one
//! refcount bump), every schedule-sensitive draw (group formation, batch
//! cursors) happens serially up front, and then each student's rating +
//! distillation runs as its own lane — students are disjoint across a
//! round's groups, so lanes never alias and results are bit-identical to
//! the serial reference (`with_parallel(false)`, pinned by
//! `tests/mkd_parallel.rs`). Round g+1's DHT matchmaking is pipelined
//! behind round g's teacher exchange, same two-lane clock attribution as
//! the MAR aggregator.

use anyhow::Result;

use crate::aggregation::robust::{
    clip_weights, krum_select, trimmed_indexed_into,
    weighted_mean_indexed_into, RobustEstimator, RobustPolicy,
};
use crate::aggregation::{mean_indexed_into, AggCtx, PeerState, Theta};
use crate::config::KdConfig;
use crate::coordinator::MarAggregator;
use crate::data::{Dataset, Shard};
use crate::exec;
use crate::metrics::Plane;
use crate::models::ModelMeta;
use crate::net::{FaultCounters, LinkFault};
use crate::runtime::Runtime;

/// What one MKD pass did.
#[derive(Clone, Copy, Debug, Default)]
pub struct KdReport {
    pub rounds: usize,
    /// teacher-model transfers booked on the data plane
    pub teacher_transfers: u64,
    /// distillation steps executed
    pub kd_steps: u64,
    /// mean student loss over the last round (diagnostic)
    pub mean_loss: f64,
    /// fault outcomes on the teacher-exchange lanes (zero when the
    /// fault plan is off)
    pub faults: FaultCounters,
    /// wall-time straggling students added to the distillation lanes
    pub straggler_exposed_s: f64,
}

/// Moshpit-KD engine.
pub struct KdEngine {
    pub cfg: KdConfig,
    tau: f32,
    eta: f32,
    mu: f32,
    /// run student lanes concurrently on the `exec` pool (default). The
    /// serial path is the bit-identical reference for the determinism
    /// tests and the MKD serial-vs-parallel ablation in `micro_hotpath`.
    pub parallel: bool,
    /// robust policy for the top-ℓ teacher-logit ensemble
    /// (`attack.robust`): a Byzantine teacher's logits are bounded the
    /// same way its model updates are in MAR groups. `Mean` (default)
    /// keeps the exact legacy f32 accumulation bit for bit.
    robust: RobustPolicy,
}

impl KdEngine {
    pub fn new(cfg: KdConfig, tau: f64, eta: f32, mu: f32) -> Self {
        KdEngine {
            cfg,
            tau: tau as f32,
            eta,
            mu,
            parallel: true,
            robust: RobustPolicy::MEAN,
        }
    }

    /// Force the serial reference engine (benchmark/verification aid).
    pub fn with_parallel(mut self, parallel: bool) -> Self {
        self.parallel = parallel;
        self
    }

    /// Select the robust policy for the teacher-logit ensemble.
    pub fn with_robust(mut self, robust: RobustPolicy) -> Self {
        self.robust = robust;
        self
    }

    /// Is MKD active in FL iteration `t` (1-based)?
    pub fn active(&self, t: usize) -> bool {
        self.cfg.enabled && t <= self.cfg.k_iterations
    }

    /// KL weight λ_t = max(0, 1 − (t−1)/K) (paper Eq. 4 with
    /// α = λ).
    pub fn lambda(&self, t: usize) -> f32 {
        let k = self.cfg.k_iterations.max(1) as f32;
        (1.0 - (t.saturating_sub(1)) as f32 / k).max(0.0)
    }

    /// Top-ℓ teacher count for `candidates` candidates (at least 1).
    pub fn top_ell(&self, candidates: usize) -> usize {
        ((candidates as f64 * self.cfg.rho_ell).round() as usize)
            .clamp(1, candidates)
    }

    /// Run the full MKD pass for FL iteration `t` (Algorithm 2 over all
    /// MKD rounds). Teacher exchange is booked on the data plane; the DHT
    /// matchmaking books its own control traffic, pipelined behind the
    /// previous round's exchange on the simulated clock.
    #[allow(clippy::too_many_arguments)]
    pub fn run_mkd(
        &self,
        t: usize,
        rt: &Runtime,
        model: &ModelMeta,
        data: &Dataset,
        shards: &mut [Shard],
        states: &mut [PeerState],
        agg: &[usize],
        mar: &mut MarAggregator,
        ctx: &mut AggCtx<'_>,
    ) -> Result<KdReport> {
        let mut report = KdReport { rounds: mar.rounds, ..Default::default() };
        let lam = self.lambda(t);
        let model_bytes = model.model_bytes();
        // fault plan: every draw happens in the serial schedule phase
        // below; with the plan off, all three axes are gated so this
        // pass consumes zero extra randomness and stays bit-identical
        let fp = ctx.faults;
        let crash_on = fp.crash_prob > 0.0;
        let link_on = fp.link_faults_enabled();
        let straggler_on = fp.straggler_prob > 0.0;
        // round 0's matchmaking is exposed on the clock; each later
        // round's pass happens while the previous teacher exchange runs
        let (mut groups, mm0) = mar.form_groups_once_timed(
            agg,
            ctx.rng,
            &format!("kd:{t}:0"),
            ctx.fabric,
        );
        // empty data lanes: advances by mm0 exactly, attributed exposed
        ctx.clock.pipelined_two_phase(mm0, std::iter::empty());
        for g in 0..mar.rounds {
            // ---- serial schedule phase -------------------------------
            // Per processed group: member peer ids, the round-start θ
            // snapshot (shared Theta handles — zero per-group copies; all
            // students distill from the same teacher parameters
            // θ_c^{g-1}), the wire booking, and every student's batch
            // indices (shard cursors are schedule state, drawn in the
            // serial reference order: group-major, member order).
            let mut lane_times = Vec::with_capacity(groups.len());
            let mut member_groups: Vec<Vec<usize>> = Vec::new();
            let mut snapshots: Vec<Vec<Theta>> = Vec::new();
            let mut batch_plans: Vec<Vec<Vec<usize>>> = Vec::new();
            for group in &groups {
                if group.len() < 2 {
                    lane_times.push(0.0);
                    continue;
                }
                let mut members: Vec<usize> =
                    group.iter().map(|&pos| agg[pos]).collect();
                // mid-exchange crashes thin the group before any transfer
                // (serial draws, member order)
                if crash_on {
                    members.retain(|_| {
                        if ctx.rng.chance(fp.crash_prob) {
                            report.faults.crashes += 1;
                            false
                        } else {
                            true
                        }
                    });
                }
                if members.len() < 2 {
                    // crashes left nobody to exchange with
                    lane_times.push(0.0);
                    continue;
                }
                // per-member link draws for the gather (serial order).
                // Decision revisited (PR 8): these draws used to be
                // i.i.d. per lane on the argument that a fan-out gather
                // has no single link to key a chain on — but the gather
                // IS k−1 directed transfers, so with a time-correlated
                // `LinkState` present each member now walks its
                // per-destination Gilbert–Elliott chains, exactly like
                // MAR's model exchange. Without one, `draw_member`
                // delegates to the seed's `draw_link(k−1)` bit for bit.
                let links: Vec<LinkFault> = if link_on {
                    members
                        .iter()
                        .map(|&src| {
                            let dsts: Vec<usize> = members
                                .iter()
                                .copied()
                                .filter(|&d| d != src)
                                .collect();
                            let lf = fp.draw_member(
                                src,
                                &dsts,
                                1,
                                ctx.links.as_deref_mut(),
                                ctx.rng,
                            );
                            report.faults.absorb(&lf);
                            lf
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                // teacher-model full-gather: θ only, k(k-1) transfers.
                // Clean links delegate to the exact legacy booking; a
                // member whose link timed out still books its attempts
                // (payload per retransmission + control-plane probes) but
                // never assembles the teacher set, so it sits the
                // distillation out.
                let mut comm = 0.0f64;
                for (j, _) in members.iter().enumerate() {
                    let dur = match links.get(j) {
                        Some(lf) => ctx.fabric.sequential_faulty(
                            members.len() - 1,
                            model_bytes,
                            Plane::Data,
                            lf,
                        ),
                        None => ctx.fabric.sequential(
                            members.len() - 1,
                            model_bytes,
                            Plane::Data,
                        ),
                    };
                    comm = dur.max(comm);
                }
                report.teacher_transfers +=
                    (members.len() * (members.len() - 1)) as u64;
                let complete: Vec<usize> = members
                    .iter()
                    .enumerate()
                    .filter(|&(j, _)| {
                        !links.get(j).is_some_and(|lf| lf.lost())
                    })
                    .map(|(_, &p)| p)
                    .collect();
                // straggler draws: a slow student's distillation lane
                // (E epochs ≈ E local batches) runs `straggler_mult`×
                // longer; the group's lane waits for its slowest student
                let mut lane = comm;
                if straggler_on {
                    for _ in &complete {
                        if ctx.rng.chance(fp.straggler_prob) {
                            let pen = self.cfg.epochs as f64
                                * crate::fl::LOCAL_BATCH_COMPUTE_S
                                * (fp.straggler_mult - 1.0);
                            report.straggler_exposed_s += pen;
                            lane = lane.max(comm + pen);
                        }
                    }
                }
                lane_times.push(lane);
                if complete.len() < 2 {
                    // the quorum drained: traffic is booked, nobody
                    // distills this round in this group
                    continue;
                }
                snapshots.push(
                    complete.iter().map(|&p| states[p].theta.clone()).collect(),
                );
                batch_plans.push(
                    complete
                        .iter()
                        .map(|&s| shards[s].next_batch(model.batch))
                        .collect(),
                );
                member_groups.push(complete);
            }
            // one lane per student: students are disjoint across the
            // round's groups, so every lane owns its peer state
            let mut flat_students: Vec<usize> = Vec::new();
            let mut lane_meta: Vec<(usize, usize)> = Vec::new();
            for (gi, members) in member_groups.iter().enumerate() {
                for (si, &peer) in members.iter().enumerate() {
                    flat_students.push(peer);
                    lane_meta.push((gi, si));
                }
            }

            // ---- concurrent distillation phase -----------------------
            // Pure function of (snapshot, batch plan, own state): safe to
            // fan out, bit-identical in any interleaving.
            let distill = |lane: usize, st: &mut PeerState| -> Result<Vec<f32>> {
                let (gi, si) = lane_meta[lane];
                let snap = &snapshots[gi];
                // the student's batch gathers into the worker's scratch
                // buffers — zero batch allocations after each worker's
                // first lane
                exec::with_scratch::<crate::data::BatchBuf, _, _>(|buf| {
                    data.gather_into_buf(&batch_plans[gi][si], buf);
                    let (x, y) = (&buf.x, &buf.y);
                    let mut s_logits =
                        Vec::with_capacity(model.batch * model.classes);
                    rt.logits_into(model, &snap[si], x, &mut s_logits)?;
                    // rate candidate teachers by softened KL on this
                    // batch; each candidate's logits land in an owned
                    // cache entry (`rated` keeps (kl, cache index) — no
                    // logit vectors are cloned or shuffled); the forward
                    // activations behind every one of these calls live in
                    // the per-worker workspace, not per-call allocations
                    let mut cache: Vec<Vec<f32>> =
                        Vec::with_capacity(snap.len() - 1);
                    let mut rated: Vec<(f64, usize)> =
                        Vec::with_capacity(snap.len() - 1);
                    for (ci, teacher) in snap.iter().enumerate() {
                        if ci == si {
                            continue;
                        }
                        let z = rt.logits(model, teacher, x)?;
                        let kl = mean_softened_kl(
                            &z,
                            &s_logits,
                            model.classes,
                            self.tau,
                        );
                        rated.push((kl, cache.len()));
                        cache.push(z);
                    }
                    // total order: NaN logits sort last, not panicking
                    rated.sort_by(|a, b| a.0.total_cmp(&b.0));
                    let ell = self.top_ell(rated.len());
                    rated.truncate(ell);
                    // z̄_b = robust center of the selected teacher logits.
                    // The `Mean` policy keeps the legacy f32 accumulation
                    // loop verbatim (bit-identical); the other estimators
                    // bound what one Byzantine teacher that survived the
                    // KL rating can inject into the distillation target.
                    let mut zbar = vec![0.0f32; model.batch * model.classes];
                    if self.robust.is_mean() || rated.len() < 2 {
                        for &(_, zi) in &rated {
                            for (a, &v) in zbar.iter_mut().zip(&cache[zi]) {
                                *a += v;
                            }
                        }
                        let inv = 1.0 / rated.len().max(1) as f32;
                        for a in &mut zbar {
                            *a *= inv;
                        }
                    } else {
                        let row = |k: usize| cache[rated[k].1].as_slice();
                        match self.robust.est {
                            RobustEstimator::NormClip => {
                                let w = clip_weights(rated.len(), row);
                                weighted_mean_indexed_into(
                                    rated.len(),
                                    row,
                                    &w,
                                    &mut zbar,
                                    false,
                                );
                            }
                            RobustEstimator::Krum
                            | RobustEstimator::MultiKrum => {
                                // selection needs ≥3 rows to leave a
                                // neighbourhood; smaller ensembles mean
                                if rated.len() < 3 {
                                    mean_indexed_into(
                                        rated.len(),
                                        row,
                                        &mut zbar,
                                        false,
                                    );
                                } else {
                                    let sel = krum_select(
                                        rated.len(),
                                        row,
                                        self.robust.krum_f(rated.len()),
                                        self.robust.est
                                            == RobustEstimator::MultiKrum,
                                    );
                                    mean_indexed_into(
                                        sel.len(),
                                        |k| row(sel[k]),
                                        &mut zbar,
                                        false,
                                    );
                                }
                            }
                            _ => trimmed_indexed_into(
                                rated.len(),
                                row,
                                &mut zbar,
                                self.robust.drop_count(rated.len()),
                                false,
                            ),
                        }
                    }
                    // E local distillation epochs, stepped in place
                    // through the copy-on-write handles: the first
                    // epoch's write detaches the student from any teacher
                    // snapshot that aliases it (so snapshots are never
                    // perturbed), and every later epoch mutates the
                    // now-unique buffer with zero state allocations
                    let mut losses = Vec::with_capacity(self.cfg.epochs);
                    for _ in 0..self.cfg.epochs {
                        let loss = rt.kd_step_into(
                            model,
                            st.theta.make_mut_slice(),
                            st.momentum.make_mut_slice(),
                            x,
                            y,
                            &zbar,
                            lam,
                            self.eta,
                            self.mu,
                        )?;
                        losses.push(loss);
                    }
                    Ok(losses)
                })
            };
            let results: Vec<Result<Vec<f32>>> = if self.parallel {
                exec::par_map_at(states, &flat_students, &distill)?
            } else {
                flat_students
                    .iter()
                    .enumerate()
                    .map(|(lane, &peer)| distill(lane, &mut states[peer]))
                    .collect()
            };
            // losses reduce in lane order — the serial reference's
            // group-major, member-order stream — so mean_loss is
            // bit-identical on both engines
            let mut loss_acc = 0.0f64;
            let mut loss_n = 0u64;
            for lane in results {
                for loss in lane? {
                    loss_acc += loss as f64;
                    loss_n += 1;
                    report.kd_steps += 1;
                }
            }
            if loss_n > 0 {
                report.mean_loss = loss_acc / loss_n as f64;
            }

            // ---- pipelined round boundary ----------------------------
            // round g+1's matchmaking overlaps this round's exchange
            let (next_groups, mm_next) = if g + 1 < mar.rounds {
                mar.form_groups_once_timed(
                    agg,
                    ctx.rng,
                    &format!("kd:{t}:{}", g + 1),
                    ctx.fabric,
                )
            } else {
                (Vec::new(), 0.0)
            };
            // teacher exchanges are pure full-gathers, so their lane
            // time books to the clock's gather accumulator — the same
            // convention MAR's full-gather mode uses (a (0.0, t) lane in
            // the two-phase model)
            ctx.clock.pipelined_two_phase(
                mm_next,
                lane_times.iter().map(|&lane| (0.0, lane)),
            );
            groups = next_groups;
        }
        Ok(report)
    }
}

/// Mean over the batch of KL(softmax(z/τ) ‖ softmax(s/τ)) — Algorithm 3's
/// teacher rating. Computed natively: logits are tiny ([B, C]) and this
/// runs inside the per-student selection loop.
pub fn mean_softened_kl(
    teacher: &[f32],
    student: &[f32],
    classes: usize,
    tau: f32,
) -> f64 {
    assert_eq!(teacher.len(), student.len());
    assert!(classes > 0 && teacher.len() % classes == 0);
    let rows = teacher.len() / classes;
    let mut total = 0.0f64;
    for r in 0..rows {
        let zt = &teacher[r * classes..(r + 1) * classes];
        let zs = &student[r * classes..(r + 1) * classes];
        let lt = log_softmax(zt, tau);
        let ls = log_softmax(zs, tau);
        let mut kl = 0.0f64;
        for c in 0..classes {
            let pt = lt[c].exp();
            kl += pt * (lt[c] - ls[c]);
        }
        total += kl;
    }
    total / rows as f64
}

fn log_softmax(z: &[f32], tau: f32) -> Vec<f64> {
    let scaled: Vec<f64> = z.iter().map(|&v| (v / tau) as f64).collect();
    let max = scaled.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let lse = scaled.iter().map(|&v| (v - max).exp()).sum::<f64>().ln() + max;
    scaled.iter().map(|&v| v - lse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(k: usize, rho: f64) -> KdEngine {
        KdEngine::new(
            KdConfig { enabled: true, k_iterations: k, rho_ell: rho, epochs: 1 },
            3.0,
            0.1,
            0.9,
        )
    }

    #[test]
    fn lambda_decays_linearly_to_zero() {
        let e = engine(8, 0.4);
        assert_eq!(e.lambda(1), 1.0);
        assert!((e.lambda(5) - 0.5).abs() < 1e-6);
        assert_eq!(e.lambda(9), 0.0);
        assert_eq!(e.lambda(100), 0.0);
    }

    #[test]
    fn active_window_is_first_k_iterations() {
        let e = engine(6, 0.4);
        assert!(e.active(1));
        assert!(e.active(6));
        assert!(!e.active(7));
        let disabled = KdEngine::new(KdConfig::default(), 3.0, 0.1, 0.9);
        assert!(!disabled.active(1));
    }

    #[test]
    fn top_ell_matches_paper_ratio() {
        let e = engine(8, 0.4);
        assert_eq!(e.top_ell(4), 2); // 40% of 4 candidates
        assert_eq!(e.top_ell(5), 2);
        assert_eq!(e.top_ell(1), 1); // never zero teachers
        assert_eq!(e.top_ell(10), 4);
    }

    #[test]
    fn kl_zero_for_identical_logits() {
        let z = vec![1.0f32, -2.0, 0.5, 3.0, 0.0, 1.0];
        assert!(mean_softened_kl(&z, &z, 3, 3.0).abs() < 1e-12);
    }

    #[test]
    fn kl_positive_and_orders_similarity() {
        let student = vec![2.0f32, 0.0, 0.0];
        let close = vec![1.8f32, 0.1, 0.0];
        let far = vec![-3.0f32, 4.0, 0.0];
        let kl_close = mean_softened_kl(&close, &student, 3, 3.0);
        let kl_far = mean_softened_kl(&far, &student, 3, 3.0);
        assert!(kl_close > 0.0);
        assert!(kl_far > kl_close, "{kl_far} vs {kl_close}");
    }

    #[test]
    fn higher_temperature_softens_divergence() {
        let a = vec![5.0f32, 0.0];
        let b = vec![0.0f32, 5.0];
        let kl_t1 = mean_softened_kl(&a, &b, 2, 1.0);
        let kl_t5 = mean_softened_kl(&a, &b, 2, 5.0);
        assert!(kl_t5 < kl_t1, "τ=5 {kl_t5} should soften vs τ=1 {kl_t1}");
    }
}
