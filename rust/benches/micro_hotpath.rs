//! Micro benchmarks of the hot paths (perf instrument for EXPERIMENTS.md
//! §Perf):
//!
//! * PJRT step latencies (train / logits / kd / eval) — the compute floor.
//! * Within-group averaging: Pallas `group_mean` artifact vs the native
//!   f64 path (ablation: which should `average_group` prefer?).
//! * Full 125-peer MAR aggregation (native) — the coordinator's own cost.
//! * DHT matchmaking round — the control-plane cost.

#[path = "common/mod.rs"]
mod common;

use common::{bench_ns, runtime, SynthBundle};
use marfl::aggregation::{average_group, Aggregate};
use marfl::coordinator::MarAggregator;
use marfl::data::synth;
use marfl::rng::Rng;

fn main() {
    let rt = runtime();
    println!("micro_hotpath — PJRT entry points\n");
    let m = rt.meta.model("cnn").unwrap().clone();
    let h = rt.meta.model("head").unwrap().clone();
    let mut rng = Rng::new(42);
    let theta = rt.init_params("cnn").unwrap();
    let mom = vec![0.0f32; theta.len()];
    let data = synth::mnist_like(m.batch, &mut rng);
    let idx: Vec<usize> = (0..m.batch).collect();
    let (x, y) = data.gather(&idx);

    let theta_h = rt.init_params("head").unwrap();
    let mom_h = vec![0.0f32; theta_h.len()];
    let data_h = synth::newsgroups_like(h.batch.max(h.eval_chunk), &mut rng);
    let idx_h: Vec<usize> = (0..h.batch).collect();
    let (xh, yh) = data_h.gather(&idx_h);
    let idx_e: Vec<usize> = (0..h.eval_chunk).collect();
    let (xe, ye) = data_h.gather(&idx_e);
    let zbar = vec![0.0f32; h.batch * h.classes];

    bench_ns("cnn train_step (B=64)", 3, 20, || {
        rt.train_step(&m, &theta, &mom, &x, &y, 0.1, 0.9).unwrap();
    });
    bench_ns("head train_step (B=16)", 3, 30, || {
        rt.train_step(&h, &theta_h, &mom_h, &xh, &yh, 0.1, 0.9).unwrap();
    });
    bench_ns("head logits (KD teacher fwd)", 3, 30, || {
        rt.logits(&h, &theta_h, &xh).unwrap();
    });
    bench_ns("head kd_step", 3, 30, || {
        rt.kd_step(&h, &theta_h, &mom_h, &xh, &yh, &zbar, 0.5, 0.1, 0.9)
            .unwrap();
    });
    bench_ns("head eval chunk (E=250)", 3, 20, || {
        rt.evaluate(&h, &theta_h, &xe, &ye).unwrap();
    });

    println!("\ngroup averaging ablation (k=5, cnn-size vectors)\n");
    let k = 5usize;
    let stack: Vec<f32> =
        (0..k * m.padded_len).map(|_| rng.normal() as f32).collect();
    bench_ns("group_mean via Pallas artifact (PJRT)", 3, 30, || {
        rt.group_mean(&m, &stack, k).unwrap();
    });
    {
        let mut b = SynthBundle::new(m.padded_len);
        let mut states = b.states(k);
        let members: Vec<usize> = (0..k).collect();
        bench_ns("group average native (f64 accumulate)", 3, 30, || {
            let mut ctx = b.ctx();
            average_group(&mut states, &members, &mut ctx).unwrap();
        });
    }

    println!("\ncoordinator-scale operations\n");
    {
        let mut b = SynthBundle::new(m.padded_len);
        let mut states = b.states(125);
        let agg: Vec<usize> = (0..125).collect();
        let mut mar = MarAggregator::new(125, 5, 3, b.ledger.clone(), 5);
        bench_ns("MAR aggregate 125 peers (native, M=5 G=3)", 1, 5, || {
            let mut ctx = b.ctx();
            mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        });
    }
    {
        let mut b = SynthBundle::new(64);
        let mut states = b.states(125);
        let agg: Vec<usize> = (0..125).collect();
        let mut mar = MarAggregator::new(125, 5, 3, b.ledger.clone(), 6);
        bench_ns("MAR matchmaking+avg 125 peers (tiny vectors)", 1, 5, || {
            let mut ctx = b.ctx();
            mar.aggregate(&mut states, &agg, &mut ctx).unwrap();
        });
    }
}
